//! Streaming pre-scorer: Algorithm 1 made *prefix-stable*.
//!
//! The batch [`prescore`](super::prescore) clusters the **full** key set, so
//! every key's score — and therefore every attention row — depends on the
//! whole context; that is exactly why the full-cluster PreScored kernel is
//! not suffix-stable and the prefix cache can only serve it full-length
//! hits. The [`StreamPrescorer`] instead processes keys **in sequence
//! order**:
//!
//! 1. *Warmup* — while `n ≤ warmup_keys` (the fixed `top_k`, or the mass
//!    floor for `Mass` budgets) the selection is the identity (the same
//!    "no filtering" convention batch prescore uses) and the raw rows are
//!    buffered.
//! 2. *Seed* — the first time `n = warmup_keys + 1`, the buffered prefix
//!    keys are batch-clustered exactly like the prefill clustering (same
//!    method route, same RNG stream as [`prescore`](super::prescore)),
//!    scored, and the budget-resolved selection is drawn from those scores
//!    ([`KeyBudget::resolve`] — exactly k for `Fixed(k)`, the realized
//!    mass-target count for `Mass(p)`). The clustering becomes a
//!    [`StreamClustering`].
//! 3. *Fold* — every later key is folded into the stream state in O(k·d)
//!    (nearest frozen centroid, running-mean re-centering) and *merged*
//!    into the selection. `Fixed(k)`: it enters iff its score beats the
//!    current minimum, evicting that minimum — an O(|S|) selection merge,
//!    never a re-cluster over all n keys. `Mass(p)`: the pool grows while
//!    its share of the total score mass is below `p` and sheds its weakest
//!    members while the target still holds without them — the total comes
//!    from the per-cluster score mass [`StreamClustering`] already tracks
//!    (plus a running min/total for the norm scorer), so each step stays
//!    O(k + |S|) with no re-sort over all keys.
//!
//! Every step is a deterministic serial function of the key sequence, so a
//! kernel that derives row `i`'s selection from the state after folding key
//! `i` has length-invariant prefix rows — the `mode=stream` suffix-stability
//! contract (see `AttentionSpec::suffix_stable`).
//!
//! Supported methods: `kmeans`, `minibatch` (ℓ2 centroid folding) and
//! `l2norm` (trivially streaming — a key's score is its own squared norm).
//! Metrics without an ℓ2 centroid-mean update (k-median, ℓp, kernel
//! k-means) and the leverage routes have no cheap fold; the spec parser
//! rejects them in stream mode.

use super::{KeyBudget, Method, PreScoreConfig};
use crate::clustering::{StreamClustering, STREAM_RECENTER_EVERY};
use crate::linalg::ops::top_k_indices;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Identity-phase placeholder score (mirrors batch prescore's `vec![1.0]`
/// identity scores); replaced wholesale when the seed clustering runs.
const WARMUP_SCORE: f32 = 1.0;

/// How the prescorer scores keys after (or instead of) the warmup phase.
#[derive(Debug, Clone, PartialEq)]
enum Scorer {
    /// Identity warmup: raw rows buffered (flat, `d` per row) until the
    /// budget is first exceeded.
    Warmup(Vec<f32>),
    /// Centroid-stream scoring (`kmeans` / `minibatch` seeds).
    Clustered(StreamClustering),
    /// ℓ2-norm scoring — stateless.
    Norms,
}

/// The persistable data half of a [`StreamPrescorer`] (configs/seeds are
/// NOT here — the restore path resupplies them, so a store can never drift
/// from the serving config). Selection *indices* live in
/// [`crate::attention::DecodeArtifacts::selection`]; this carries the
/// aligned scores plus the clustering state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamArtifacts {
    /// 0 = warmup, 1 = clustered, 2 = norms (`Scorer` tag).
    pub scorer: u8,
    /// Buffered raw rows (flat) — warmup only.
    pub warmup: Vec<f32>,
    /// Clustered state: centroids then sums, both flat k×d — clustered only.
    pub centroids: Vec<f32>,
    pub sums: Vec<f32>,
    pub counts: Vec<u32>,
    pub score_mass: Vec<f32>,
    pub since_recenter: u32,
    /// Scores aligned with the exported selection.
    pub sel_scores: Vec<f32>,
    /// Keys folded so far (= context positions covered).
    pub folded: u32,
    /// Minimum score observed over every folded key (mass-budget shift
    /// point; see [`KeyBudget`]). `0` while warming up.
    pub score_min: f32,
    /// Running Σ of fold-time scores (the norm scorer's mass total; the
    /// clustered scorer re-derives its total from `score_mass`).
    pub score_total: f32,
}

/// Streaming replacement for `prescore`: one instance per layer·head decode
/// state, folded forward one key at a time.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamPrescorer {
    cfg: PreScoreConfig,
    d: usize,
    scorer: Scorer,
    /// Current selection, ascending positions.
    selection: Vec<usize>,
    /// Scores aligned with `selection`.
    sel_scores: Vec<f32>,
    /// Keys folded so far.
    folded: usize,
    /// Minimum score over every folded key (mass-budget shift point).
    score_min: f32,
    /// Running Σ of fold-time scores (used by the norm scorer; the
    /// clustered scorer reuses [`StreamClustering::score_mass`]).
    score_total: f32,
}

impl StreamPrescorer {
    /// Whether `method` has a streaming fold (the spec parser gates
    /// `mode=stream` on this).
    pub fn supports(method: Method) -> bool {
        matches!(
            method,
            Method::KMeans | Method::MiniBatch { .. } | Method::L2Norm
        )
    }

    /// Fresh state over a `d`-dimensional key stream. Panics on an
    /// unsupported method — the spec parser is the guard.
    pub fn new(cfg: PreScoreConfig, d: usize) -> StreamPrescorer {
        assert!(
            Self::supports(cfg.method),
            "prescore method {:?} has no streaming fold (mode=stream supports \
             kmeans | minibatch | l2norm)",
            cfg.method
        );
        StreamPrescorer {
            cfg,
            d,
            scorer: Scorer::Warmup(Vec::new()),
            selection: Vec::new(),
            sel_scores: Vec::new(),
            folded: 0,
            score_min: 0.0,
            score_total: 0.0,
        }
    }

    /// Keys folded so far.
    pub fn len(&self) -> usize {
        self.folded
    }

    pub fn is_empty(&self) -> bool {
        self.folded == 0
    }

    /// Current selection (ascending). Identity during warmup; exactly
    /// `top_k` once seeded for `Fixed(top_k > 0)`, the mass-resolved count
    /// for `Mass(p < 1)`.
    pub fn selection(&self) -> &[usize] {
        &self.selection
    }

    /// Fold the next key row (sequence order). O(k·d + |S|).
    pub fn fold(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d, "fold dim mismatch");
        let pos = self.folded;
        self.folded += 1;
        if self.cfg.budget.never_restricts() {
            // The paper's "no filtering" convention: identity selection.
            self.selection.push(pos);
            self.sel_scores.push(WARMUP_SCORE);
            return;
        }
        let score = match &mut self.scorer {
            Scorer::Warmup(buf) => {
                buf.extend_from_slice(row);
                self.selection.push(pos);
                self.sel_scores.push(WARMUP_SCORE);
                if self.folded == self.cfg.budget.warmup_keys() + 1 {
                    self.seed();
                }
                return;
            }
            Scorer::Clustered(sc) => {
                if self.cfg.normalize {
                    let mut r = row.to_vec();
                    normalize_row(&mut r);
                    sc.fold_key(&r).1
                } else {
                    sc.fold_key(row).1
                }
            }
            Scorer::Norms => row.iter().map(|x| x * x).sum(),
        };
        // Mass-budget aggregates cover every folded key, the new one
        // included, so they update before the selection merge.
        self.score_min = self.score_min.min(score);
        self.score_total += score;
        self.merge(pos, score);
    }

    /// Fold every not-yet-folded key of `k` (rows `len()..k.rows`) — the
    /// decode-refresh / replay helper. O(|new keys|·k·d), independent of the
    /// prefix length.
    pub fn fold_to(&mut self, k: &Matrix) {
        for pos in self.folded..k.rows {
            self.fold(k.row(pos));
        }
    }

    /// First crossing of the warmup boundary: batch-cluster the buffered
    /// prefix keys exactly as the prefill clustering would (same method
    /// route and RNG stream as [`super::prescore`]), score them, and keep
    /// the budget-resolved top scores ([`KeyBudget::resolve`] — shared with
    /// batch prescore, so the seed selection matches the batch selection
    /// over the same prefix for both budget forms).
    fn seed(&mut self) {
        let Scorer::Warmup(buf) = &self.scorer else {
            unreachable!("seed() outside warmup")
        };
        let n = self.folded;
        debug_assert_eq!(buf.len(), n * self.d, "warmup buffer out of sync");
        let raw = Matrix::from_vec(n, self.d, buf.clone());
        let (next, scores) = match self.cfg.method {
            Method::L2Norm => (Scorer::Norms, raw.row_sq_norms()),
            method => {
                let mut kp = raw;
                if self.cfg.normalize {
                    kp.l2_normalize_rows(1e-12);
                }
                // Exactly the batch prescore() route: same cluster count,
                // same RNG stream, same per-method clustering call — all
                // single-sourced in prescore/mod.rs so they cannot drift.
                let k_clusters = super::prescore_cluster_count(self.cfg.clusters, self.d, n);
                let mut rng = Rng::with_stream(self.cfg.seed, super::PRESCORE_RNG_STREAM);
                let c =
                    super::l2_cluster_route(&kp, method, k_clusters, self.cfg.max_iters, &mut rng);
                let scores: Vec<f32> =
                    c.distances_sq(&kp).into_iter().map(|d| -d).collect();
                (
                    Scorer::Clustered(StreamClustering::from_clustering(
                        &c,
                        &kp,
                        STREAM_RECENTER_EVERY,
                    )),
                    scores,
                )
            }
        };
        self.score_min = scores.iter().copied().fold(f32::INFINITY, f32::min);
        self.score_total = scores.iter().sum();
        let s = self.cfg.budget.resolve(&scores);
        let mut selection = top_k_indices(&scores, s);
        selection.sort_unstable();
        self.sel_scores = selection.iter().map(|&i| scores[i]).collect();
        self.selection = selection;
        self.scorer = next;
    }

    /// Total score mass over every folded key. For the clustered scorer
    /// this reuses the per-cluster score mass [`StreamClustering`] already
    /// tracks (each `fold_key` adds its fold-time score to its cluster's
    /// bucket, and the seed pass charges the prefix), so resolving a mass
    /// budget per step is O(k) — no pass over unselected keys. The norm
    /// scorer keeps a running total instead.
    fn total_score(&self) -> f64 {
        match &self.scorer {
            Scorer::Clustered(sc) => sc.score_mass().iter().map(|&m| m as f64).sum(),
            Scorer::Norms => self.score_total as f64,
            Scorer::Warmup(_) => 0.0,
        }
    }

    /// Selection merge, post-seed. `Fixed(k)`: the new key enters iff its
    /// score beats the current minimum (strictly — ties keep the
    /// incumbent), evicting the earliest position among the minima.
    /// `Mass(p)`: admit/shed toward the mass target instead. Both keep
    /// `selection` ascending because the new position is always the largest
    /// and evictions preserve order.
    fn merge(&mut self, pos: usize, score: f32) {
        let cap = match self.cfg.budget {
            KeyBudget::Fixed(top_k) => top_k,
            KeyBudget::Mass(p) => {
                self.merge_mass(pos, score, p);
                return;
            }
        };
        if self.selection.len() < cap {
            self.selection.push(pos);
            self.sel_scores.push(score);
            return;
        }
        let mut mi = 0usize;
        for i in 1..self.sel_scores.len() {
            if self.sel_scores[i] < self.sel_scores[mi] {
                mi = i;
            }
        }
        if score > self.sel_scores[mi] {
            self.selection.remove(mi);
            self.sel_scores.remove(mi);
            self.selection.push(pos);
            self.sel_scores.push(score);
        }
    }

    /// Mass-budget pool maintenance, O(k + |S|) per fold: the pool *grows*
    /// (admits the new key unconditionally) while its share of the total
    /// shifted score mass is below the target `p`, otherwise the new key
    /// must strictly beat the pool minimum exactly as under a fixed budget;
    /// it then *sheds* weakest-first while the target still holds without
    /// the shed key. Floor and cap match [`KeyBudget::resolve`], so the
    /// pool tracks the batch resolution of the same target.
    fn merge_mass(&mut self, pos: usize, score: f32, p: f32) {
        let n = self.folded;
        let floor = KeyBudget::MASS_FLOOR_KEYS.min(n).max(1);
        let cap = KeyBudget::MASS_CAP_KEYS.min(n);
        let lo = self.score_min as f64;
        let total = (self.total_score() - n as f64 * lo).max(0.0);
        // Degenerate flat distribution (every score equal): fall back to
        // the batch convention's count target ceil(p·n).
        let flat_want = if total <= 0.0 {
            Some((((p as f64) * n as f64).ceil() as usize).clamp(floor, cap))
        } else {
            None
        };
        let target = p as f64 * total;
        let pool_mass =
            |sel: &[f32]| sel.iter().map(|&s| s as f64 - lo).sum::<f64>();
        let under_target = match flat_want {
            Some(want) => self.selection.len() < want,
            None => pool_mass(&self.sel_scores) < target,
        };
        if self.selection.len() < floor || (self.selection.len() < cap && under_target) {
            self.selection.push(pos);
            self.sel_scores.push(score);
        } else {
            let mut mi = 0usize;
            for i in 1..self.sel_scores.len() {
                if self.sel_scores[i] < self.sel_scores[mi] {
                    mi = i;
                }
            }
            if score > self.sel_scores[mi] {
                self.selection.remove(mi);
                self.sel_scores.remove(mi);
                self.selection.push(pos);
                self.sel_scores.push(score);
            }
        }
        while self.selection.len() > floor {
            let mut mi = 0usize;
            for i in 1..self.sel_scores.len() {
                if self.sel_scores[i] < self.sel_scores[mi] {
                    mi = i;
                }
            }
            let shed = match flat_want {
                Some(want) => self.selection.len() > want,
                None => {
                    pool_mass(&self.sel_scores) - (self.sel_scores[mi] as f64 - lo)
                        >= target
                }
            };
            if self.selection.len() > cap || shed {
                self.selection.remove(mi);
                self.sel_scores.remove(mi);
            } else {
                break;
            }
        }
    }

    /// Export the persistable data half (pair with the selection indices the
    /// decode artifacts already carry).
    pub fn export(&self) -> StreamArtifacts {
        let mut art = StreamArtifacts {
            sel_scores: self.sel_scores.clone(),
            folded: self.folded as u32,
            score_min: self.score_min,
            score_total: self.score_total,
            ..Default::default()
        };
        match &self.scorer {
            Scorer::Warmup(buf) => {
                art.scorer = 0;
                art.warmup = buf.clone();
            }
            Scorer::Clustered(sc) => {
                art.scorer = 1;
                let (centroids, sums, counts, mass, since, _) = sc.to_parts();
                art.centroids = centroids.data.clone();
                art.sums = sums.data.clone();
                art.counts = counts.iter().map(|&c| c as u32).collect();
                art.score_mass = mass.to_vec();
                art.since_recenter = since as u32;
            }
            Scorer::Norms => art.scorer = 2,
        }
        art
    }

    /// Rebuild from persisted artifacts + the selection the decode
    /// artifacts carry. `None` on any shape/tag mismatch (the persist
    /// loader surfaces it as a restore failure).
    pub fn restore(
        cfg: PreScoreConfig,
        d: usize,
        selection: &[usize],
        art: &StreamArtifacts,
    ) -> Option<StreamPrescorer> {
        if !Self::supports(cfg.method) || art.sel_scores.len() != selection.len() {
            return None;
        }
        let scorer = match art.scorer {
            0 => {
                // Warmup buffers one raw row per folded key — except under
                // a never-restricting budget (Fixed(0) / Mass(1.0)), where
                // folds are identity-only and buffer nothing. A store whose
                // buffer disagrees with its fold count, or that claims a
                // warmup past the seed boundary (seeding fires at exactly
                // warmup_keys + 1 folds, so a warmup state with folded >
                // warmup_keys could never have been exported and would
                // never seed), must be refused here, not mis-serve or panic
                // later.
                let expected = if cfg.budget.never_restricts() {
                    0
                } else {
                    art.folded as usize * d
                };
                if art.warmup.len() != expected {
                    return None;
                }
                if !cfg.budget.never_restricts()
                    && art.folded as usize > cfg.budget.warmup_keys()
                {
                    return None;
                }
                Scorer::Warmup(art.warmup.clone())
            }
            1 => {
                // A clustered state with no centroids can never have been
                // exported (seeding clamps k ≥ 1); folding into it would
                // panic, so refuse the store here. Every companion array
                // must agree on k BEFORE the Matrix constructors run —
                // `Matrix::from_vec` asserts, and a corrupt store must be
                // refused, not panic the load.
                if d == 0 || art.centroids.is_empty() || art.centroids.len() % d != 0 {
                    return None;
                }
                let k = art.centroids.len() / d;
                if art.sums.len() != art.centroids.len()
                    || art.counts.len() != k
                    || art.score_mass.len() != k
                {
                    return None;
                }
                Scorer::Clustered(StreamClustering::from_parts(
                    Matrix::from_vec(k, d, art.centroids.clone()),
                    Matrix::from_vec(k, d, art.sums.clone()),
                    art.counts.iter().map(|&c| c as usize).collect(),
                    art.score_mass.clone(),
                    art.since_recenter as usize,
                    STREAM_RECENTER_EVERY,
                )?)
            }
            2 => Scorer::Norms,
            _ => return None,
        };
        Some(StreamPrescorer {
            cfg,
            d,
            scorer,
            selection: selection.to_vec(),
            sel_scores: art.sel_scores.clone(),
            folded: art.folded as usize,
            score_min: art.score_min,
            score_total: art.score_total,
        })
    }
}

/// ℓ2-normalize one row in place — elementwise identical to
/// [`Matrix::l2_normalize_rows`] with `eps = 1e-12`, so a key folded
/// incrementally is normalized exactly as the batch path would normalize it.
fn normalize_row(row: &mut [f32]) {
    let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        let inv = 1.0 / norm;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(top_k: usize) -> PreScoreConfig {
        PreScoreConfig { budget: KeyBudget::Fixed(top_k), seed: 7, ..Default::default() }
    }

    fn mass_cfg(p: f32) -> PreScoreConfig {
        PreScoreConfig { budget: KeyBudget::Mass(p), seed: 7, ..Default::default() }
    }

    fn keys(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(n, d, 1.0, &mut rng)
    }

    #[test]
    fn warmup_is_identity_then_seeds_to_budget() {
        let k = keys(40, 6, 1);
        let mut p = StreamPrescorer::new(cfg(12), 6);
        for i in 0..12 {
            p.fold(k.row(i));
            assert_eq!(p.selection(), (0..=i).collect::<Vec<_>>().as_slice());
        }
        p.fold(k.row(12)); // crosses the budget → seed clustering fires
        assert_eq!(p.selection().len(), 12);
        for i in 13..40 {
            p.fold(k.row(i));
            assert_eq!(p.selection().len(), 12, "selection stays at top_k");
        }
        let sel = p.selection();
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "ascending: {sel:?}");
        assert!(sel.iter().all(|&j| j < 40));
    }

    #[test]
    fn top_k_zero_is_identity_forever() {
        let k = keys(30, 4, 2);
        let mut p = StreamPrescorer::new(cfg(0), 4);
        p.fold_to(&k);
        assert_eq!(p.selection(), (0..30).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn folding_is_prefix_stable() {
        // fold_to in one go ≡ two gos ≡ per-row — bitwise.
        let k = keys(90, 5, 3);
        for method in [Method::KMeans, Method::MiniBatch { batch: 16 }, Method::L2Norm] {
            let c = PreScoreConfig { method, ..cfg(16) };
            let mut a = StreamPrescorer::new(c.clone(), 5);
            a.fold_to(&k);
            let mut b = StreamPrescorer::new(c.clone(), 5);
            b.fold_to(&k.slice_rows(0, 37));
            b.fold_to(&k);
            assert_eq!(a, b, "{method:?}");
        }
    }

    #[test]
    fn seed_clustering_matches_batch_prescore_selection() {
        // At the seed boundary (n = top_k + 1) the streamed state has run
        // exactly the prefill clustering, so its selection must equal batch
        // prescore's over the same keys — pins the shared cluster route
        // (count formula, RNG stream, per-method call) against drift.
        let k = keys(40, 6, 9);
        for method in [Method::KMeans, Method::MiniBatch { batch: 16 }] {
            let c = PreScoreConfig { method, ..cfg(12) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, 13)); // crosses the budget → seeds
            let batch = super::super::prescore(&k.slice_rows(0, 13), &c);
            assert_eq!(p.selection(), batch.selected.as_slice(), "{method:?}");
        }
    }

    #[test]
    fn l2norm_stream_matches_batch_selection() {
        // ℓ2-norm scores are per-key, so the streamed top-k equals batch
        // prescore's selection exactly.
        let k = keys(64, 4, 4);
        let c = PreScoreConfig { method: Method::L2Norm, ..cfg(10) };
        let mut p = StreamPrescorer::new(c.clone(), 4);
        p.fold_to(&k);
        let batch = super::super::prescore(&k, &c);
        assert_eq!(p.selection(), batch.selected.as_slice());
    }

    #[test]
    fn merge_evicts_minimum_only_on_strict_beat() {
        let c = PreScoreConfig { method: Method::L2Norm, ..cfg(2) };
        let mut p = StreamPrescorer::new(c, 1);
        // rows are 1-d; score = x².
        p.fold(&[3.0]); // warmup
        p.fold(&[1.0]); // warmup
        p.fold(&[2.0]); // seeds over {9,1,4} → keep {0,2}
        assert_eq!(p.selection(), &[0, 2]);
        p.fold(&[2.0]); // score 4 == min 4 → tie keeps incumbent
        assert_eq!(p.selection(), &[0, 2]);
        p.fold(&[5.0]); // 25 > 4 → evict pos 2
        assert_eq!(p.selection(), &[0, 4]);
    }

    #[test]
    fn export_restore_roundtrip_all_phases() {
        let k = keys(50, 6, 5);
        for (method, upto) in [
            (Method::KMeans, 8usize),  // warmup phase (top_k=16 below)
            (Method::KMeans, 50),      // clustered phase
            (Method::L2Norm, 50),      // norms phase
        ] {
            let c = PreScoreConfig { method, ..cfg(16) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, upto));
            let art = p.export();
            let back = StreamPrescorer::restore(c.clone(), 6, p.selection(), &art)
                .expect("restore");
            assert_eq!(back, p, "{method:?} upto {upto}");
            // Restored state keeps folding identically.
            let mut cont = back;
            let mut orig = p;
            cont.fold(&[0.5; 6]);
            orig.fold(&[0.5; 6]);
            assert_eq!(cont, orig);
        }
        // Mismatched selection/scores refuse to restore.
        let c = cfg(4);
        let p = StreamPrescorer::new(c.clone(), 6);
        let art = p.export();
        assert!(StreamPrescorer::restore(c, 6, &[0, 1], &art).is_none());
        // A warmup buffer inconsistent with the fold count is refused at
        // restore time (it would otherwise panic a later seed()).
        let c = cfg(16);
        let mut p = StreamPrescorer::new(c.clone(), 6);
        p.fold_to(&k.slice_rows(0, 4));
        let mut art = p.export();
        art.warmup.truncate(6); // one row left for four folded keys
        assert!(StreamPrescorer::restore(c, 6, p.selection(), &art).is_none());
    }

    #[test]
    fn mass_one_is_identity_forever() {
        // Mass(1.0) routes through the same never-restricts branch as
        // Fixed(0): bitwise-identical identity state, never seeds.
        let k = keys(30, 4, 2);
        let mut full = StreamPrescorer::new(mass_cfg(1.0), 4);
        let mut zero = StreamPrescorer::new(cfg(0), 4);
        full.fold_to(&k);
        zero.fold_to(&k);
        assert_eq!(full.selection(), (0..30).collect::<Vec<_>>().as_slice());
        assert_eq!(full.selection(), zero.selection());
        assert_eq!(full.export(), zero.export());
    }

    #[test]
    fn mass_folding_is_prefix_stable() {
        let k = keys(90, 5, 3);
        for method in [Method::KMeans, Method::MiniBatch { batch: 16 }, Method::L2Norm] {
            let c = PreScoreConfig { method, ..mass_cfg(0.7) };
            let mut a = StreamPrescorer::new(c.clone(), 5);
            a.fold_to(&k);
            let mut b = StreamPrescorer::new(c.clone(), 5);
            b.fold_to(&k.slice_rows(0, 37));
            b.fold_to(&k);
            assert_eq!(a, b, "{method:?}");
        }
    }

    #[test]
    fn mass_seed_matches_batch_prescore_selection() {
        // At the seed boundary the stream resolves the mass budget through
        // the same KeyBudget::resolve over the same batch scores, so the
        // seed selection equals batch prescore's over the same prefix.
        let upto = KeyBudget::MASS_FLOOR_KEYS + 1;
        let k = keys(40, 6, 9);
        for method in [Method::KMeans, Method::L2Norm] {
            let c = PreScoreConfig { method, ..mass_cfg(0.8) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, upto)); // crosses the floor → seeds
            let batch = super::super::prescore(&k.slice_rows(0, upto), &c);
            assert_eq!(p.selection(), batch.selected.as_slice(), "{method:?}");
        }
    }

    #[test]
    fn mass_pool_respects_floor_and_grows_with_target() {
        let k = keys(120, 6, 11);
        let mut sizes = Vec::new();
        for p in [0.25f32, 0.95] {
            let mut s = StreamPrescorer::new(mass_cfg(p), 6);
            s.fold_to(&k);
            let sel = s.selection();
            assert!(sel.len() >= KeyBudget::MASS_FLOOR_KEYS, "floor holds at p={p}");
            assert!(sel.len() <= 120);
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "ascending: {sel:?}");
            sizes.push(sel.len());
        }
        // The stream pool is path-dependent, so only the wide-gap ordering
        // is asserted here; exact monotonicity in p is pinned on the batch
        // resolver (rust/tests/budget.rs).
        assert!(sizes[0] <= sizes[1], "p=0.25 retains no more than p=0.95: {sizes:?}");
    }

    #[test]
    fn mass_export_restore_roundtrip() {
        let k = keys(50, 6, 5);
        for (method, upto) in [
            (Method::KMeans, 4usize), // warmup phase (floor = 8)
            (Method::KMeans, 50),     // clustered phase
            (Method::L2Norm, 50),     // norms phase
        ] {
            let c = PreScoreConfig { method, ..mass_cfg(0.75) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, upto));
            let art = p.export();
            let back = StreamPrescorer::restore(c.clone(), 6, p.selection(), &art)
                .expect("restore");
            assert_eq!(back, p, "{method:?} upto {upto}");
            let mut cont = back;
            let mut orig = p;
            cont.fold(&[0.5; 6]);
            orig.fold(&[0.5; 6]);
            assert_eq!(cont, orig, "mass aggregates survive the round-trip");
        }
    }

    #[test]
    #[should_panic(expected = "no streaming fold")]
    fn unsupported_method_panics() {
        StreamPrescorer::new(
            PreScoreConfig { method: Method::KMedian, ..cfg(8) },
            4,
        );
    }
}
