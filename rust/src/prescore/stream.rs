//! Streaming pre-scorer: Algorithm 1 made *prefix-stable*.
//!
//! The batch [`prescore`](super::prescore) clusters the **full** key set, so
//! every key's score — and therefore every attention row — depends on the
//! whole context; that is exactly why the full-cluster PreScored kernel is
//! not suffix-stable and the prefix cache can only serve it full-length
//! hits. The [`StreamPrescorer`] instead processes keys **in sequence
//! order**:
//!
//! 1. *Warmup* — while `n ≤ top_k` the selection is the identity (the same
//!    "no filtering" convention batch prescore uses) and the raw rows are
//!    buffered.
//! 2. *Seed* — the first time `n = top_k + 1`, the buffered prefix keys are
//!    batch-clustered exactly like the prefill clustering (same method
//!    route, same RNG stream as [`prescore`](super::prescore)), scored, and
//!    the top-k selection is drawn from those scores. The clustering
//!    becomes a [`StreamClustering`].
//! 3. *Fold* — every later key is folded into the stream state in O(k·d)
//!    (nearest frozen centroid, running-mean re-centering) and *merged*
//!    into the selection: it enters iff its score beats the current
//!    minimum, evicting that minimum — an O(|S|) selection merge, never a
//!    re-cluster over all n keys.
//!
//! Every step is a deterministic serial function of the key sequence, so a
//! kernel that derives row `i`'s selection from the state after folding key
//! `i` has length-invariant prefix rows — the `mode=stream` suffix-stability
//! contract (see `AttentionSpec::suffix_stable`).
//!
//! Supported methods: `kmeans`, `minibatch` (ℓ2 centroid folding) and
//! `l2norm` (trivially streaming — a key's score is its own squared norm).
//! Metrics without an ℓ2 centroid-mean update (k-median, ℓp, kernel
//! k-means) and the leverage routes have no cheap fold; the spec parser
//! rejects them in stream mode.

use super::{Method, PreScoreConfig};
use crate::clustering::{StreamClustering, STREAM_RECENTER_EVERY};
use crate::linalg::ops::top_k_indices;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Identity-phase placeholder score (mirrors batch prescore's `vec![1.0]`
/// identity scores); replaced wholesale when the seed clustering runs.
const WARMUP_SCORE: f32 = 1.0;

/// How the prescorer scores keys after (or instead of) the warmup phase.
#[derive(Debug, Clone, PartialEq)]
enum Scorer {
    /// Identity warmup: raw rows buffered (flat, `d` per row) until the
    /// budget is first exceeded.
    Warmup(Vec<f32>),
    /// Centroid-stream scoring (`kmeans` / `minibatch` seeds).
    Clustered(StreamClustering),
    /// ℓ2-norm scoring — stateless.
    Norms,
}

/// The persistable data half of a [`StreamPrescorer`] (configs/seeds are
/// NOT here — the restore path resupplies them, so a store can never drift
/// from the serving config). Selection *indices* live in
/// [`crate::attention::DecodeArtifacts::selection`]; this carries the
/// aligned scores plus the clustering state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamArtifacts {
    /// 0 = warmup, 1 = clustered, 2 = norms (`Scorer` tag).
    pub scorer: u8,
    /// Buffered raw rows (flat) — warmup only.
    pub warmup: Vec<f32>,
    /// Clustered state: centroids then sums, both flat k×d — clustered only.
    pub centroids: Vec<f32>,
    pub sums: Vec<f32>,
    pub counts: Vec<u32>,
    pub score_mass: Vec<f32>,
    pub since_recenter: u32,
    /// Scores aligned with the exported selection.
    pub sel_scores: Vec<f32>,
    /// Keys folded so far (= context positions covered).
    pub folded: u32,
}

/// Streaming replacement for `prescore`: one instance per layer·head decode
/// state, folded forward one key at a time.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamPrescorer {
    cfg: PreScoreConfig,
    d: usize,
    scorer: Scorer,
    /// Current selection, ascending positions.
    selection: Vec<usize>,
    /// Scores aligned with `selection`.
    sel_scores: Vec<f32>,
    /// Keys folded so far.
    folded: usize,
}

impl StreamPrescorer {
    /// Whether `method` has a streaming fold (the spec parser gates
    /// `mode=stream` on this).
    pub fn supports(method: Method) -> bool {
        matches!(
            method,
            Method::KMeans | Method::MiniBatch { .. } | Method::L2Norm
        )
    }

    /// Fresh state over a `d`-dimensional key stream. Panics on an
    /// unsupported method — the spec parser is the guard.
    pub fn new(cfg: PreScoreConfig, d: usize) -> StreamPrescorer {
        assert!(
            Self::supports(cfg.method),
            "prescore method {:?} has no streaming fold (mode=stream supports \
             kmeans | minibatch | l2norm)",
            cfg.method
        );
        StreamPrescorer {
            cfg,
            d,
            scorer: Scorer::Warmup(Vec::new()),
            selection: Vec::new(),
            sel_scores: Vec::new(),
            folded: 0,
        }
    }

    /// Keys folded so far.
    pub fn len(&self) -> usize {
        self.folded
    }

    pub fn is_empty(&self) -> bool {
        self.folded == 0
    }

    /// Current selection (ascending). Identity during warmup; exactly
    /// `top_k` once seeded (for `top_k > 0`).
    pub fn selection(&self) -> &[usize] {
        &self.selection
    }

    /// Fold the next key row (sequence order). O(k·d + |S|).
    pub fn fold(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d, "fold dim mismatch");
        let pos = self.folded;
        self.folded += 1;
        let top_k = self.cfg.top_k;
        if top_k == 0 {
            // The paper's "no filtering" convention: identity selection.
            self.selection.push(pos);
            self.sel_scores.push(WARMUP_SCORE);
            return;
        }
        let score = match &mut self.scorer {
            Scorer::Warmup(buf) => {
                buf.extend_from_slice(row);
                self.selection.push(pos);
                self.sel_scores.push(WARMUP_SCORE);
                if self.folded == top_k + 1 {
                    self.seed();
                }
                return;
            }
            Scorer::Clustered(sc) => {
                if self.cfg.normalize {
                    let mut r = row.to_vec();
                    normalize_row(&mut r);
                    sc.fold_key(&r).1
                } else {
                    sc.fold_key(row).1
                }
            }
            Scorer::Norms => row.iter().map(|x| x * x).sum(),
        };
        self.merge(pos, score);
    }

    /// Fold every not-yet-folded key of `k` (rows `len()..k.rows`) — the
    /// decode-refresh / replay helper. O(|new keys|·k·d), independent of the
    /// prefix length.
    pub fn fold_to(&mut self, k: &Matrix) {
        for pos in self.folded..k.rows {
            self.fold(k.row(pos));
        }
    }

    /// First crossing of the budget: batch-cluster the buffered prefix keys
    /// exactly as the prefill clustering would (same method route and RNG
    /// stream as [`super::prescore`]), score them, and keep the top-k.
    fn seed(&mut self) {
        let Scorer::Warmup(buf) = &self.scorer else {
            unreachable!("seed() outside warmup")
        };
        let n = self.folded;
        debug_assert_eq!(buf.len(), n * self.d, "warmup buffer out of sync");
        let raw = Matrix::from_vec(n, self.d, buf.clone());
        let (next, scores) = match self.cfg.method {
            Method::L2Norm => (Scorer::Norms, raw.row_sq_norms()),
            method => {
                let mut kp = raw;
                if self.cfg.normalize {
                    kp.l2_normalize_rows(1e-12);
                }
                // Exactly the batch prescore() route: same cluster count,
                // same RNG stream, same per-method clustering call — all
                // single-sourced in prescore/mod.rs so they cannot drift.
                let k_clusters = super::prescore_cluster_count(self.cfg.clusters, self.d, n);
                let mut rng = Rng::with_stream(self.cfg.seed, super::PRESCORE_RNG_STREAM);
                let c =
                    super::l2_cluster_route(&kp, method, k_clusters, self.cfg.max_iters, &mut rng);
                let scores: Vec<f32> =
                    c.distances_sq(&kp).into_iter().map(|d| -d).collect();
                (
                    Scorer::Clustered(StreamClustering::from_clustering(
                        &c,
                        &kp,
                        STREAM_RECENTER_EVERY,
                    )),
                    scores,
                )
            }
        };
        let mut selection = top_k_indices(&scores, self.cfg.top_k);
        selection.sort_unstable();
        self.sel_scores = selection.iter().map(|&i| scores[i]).collect();
        self.selection = selection;
        self.scorer = next;
    }

    /// Selection merge: the new key enters iff its score beats the current
    /// minimum (strictly — ties keep the incumbent), evicting the earliest
    /// position among the minima. Keeps `selection` ascending because the
    /// new position is always the largest.
    fn merge(&mut self, pos: usize, score: f32) {
        if self.selection.len() < self.cfg.top_k {
            self.selection.push(pos);
            self.sel_scores.push(score);
            return;
        }
        let mut mi = 0usize;
        for i in 1..self.sel_scores.len() {
            if self.sel_scores[i] < self.sel_scores[mi] {
                mi = i;
            }
        }
        if score > self.sel_scores[mi] {
            self.selection.remove(mi);
            self.sel_scores.remove(mi);
            self.selection.push(pos);
            self.sel_scores.push(score);
        }
    }

    /// Export the persistable data half (pair with the selection indices the
    /// decode artifacts already carry).
    pub fn export(&self) -> StreamArtifacts {
        let mut art = StreamArtifacts {
            sel_scores: self.sel_scores.clone(),
            folded: self.folded as u32,
            ..Default::default()
        };
        match &self.scorer {
            Scorer::Warmup(buf) => {
                art.scorer = 0;
                art.warmup = buf.clone();
            }
            Scorer::Clustered(sc) => {
                art.scorer = 1;
                let (centroids, sums, counts, mass, since, _) = sc.to_parts();
                art.centroids = centroids.data.clone();
                art.sums = sums.data.clone();
                art.counts = counts.iter().map(|&c| c as u32).collect();
                art.score_mass = mass.to_vec();
                art.since_recenter = since as u32;
            }
            Scorer::Norms => art.scorer = 2,
        }
        art
    }

    /// Rebuild from persisted artifacts + the selection the decode
    /// artifacts carry. `None` on any shape/tag mismatch (the persist
    /// loader surfaces it as a restore failure).
    pub fn restore(
        cfg: PreScoreConfig,
        d: usize,
        selection: &[usize],
        art: &StreamArtifacts,
    ) -> Option<StreamPrescorer> {
        if !Self::supports(cfg.method) || art.sel_scores.len() != selection.len() {
            return None;
        }
        let scorer = match art.scorer {
            0 => {
                // Warmup buffers one raw row per folded key — except under
                // top_k = 0, where folds are identity-only and buffer
                // nothing. A store whose buffer disagrees with its fold
                // count, or that claims a warmup past the seed boundary
                // (seeding fires at exactly top_k + 1 folds, so a warmup
                // state with folded > top_k could never have been exported
                // and would never seed), must be refused here, not
                // mis-serve or panic later.
                let expected = if cfg.top_k == 0 { 0 } else { art.folded as usize * d };
                if art.warmup.len() != expected {
                    return None;
                }
                if cfg.top_k != 0 && art.folded as usize > cfg.top_k {
                    return None;
                }
                Scorer::Warmup(art.warmup.clone())
            }
            1 => {
                // A clustered state with no centroids can never have been
                // exported (seeding clamps k ≥ 1); folding into it would
                // panic, so refuse the store here. Every companion array
                // must agree on k BEFORE the Matrix constructors run —
                // `Matrix::from_vec` asserts, and a corrupt store must be
                // refused, not panic the load.
                if d == 0 || art.centroids.is_empty() || art.centroids.len() % d != 0 {
                    return None;
                }
                let k = art.centroids.len() / d;
                if art.sums.len() != art.centroids.len()
                    || art.counts.len() != k
                    || art.score_mass.len() != k
                {
                    return None;
                }
                Scorer::Clustered(StreamClustering::from_parts(
                    Matrix::from_vec(k, d, art.centroids.clone()),
                    Matrix::from_vec(k, d, art.sums.clone()),
                    art.counts.iter().map(|&c| c as usize).collect(),
                    art.score_mass.clone(),
                    art.since_recenter as usize,
                    STREAM_RECENTER_EVERY,
                )?)
            }
            2 => Scorer::Norms,
            _ => return None,
        };
        Some(StreamPrescorer {
            cfg,
            d,
            scorer,
            selection: selection.to_vec(),
            sel_scores: art.sel_scores.clone(),
            folded: art.folded as usize,
        })
    }
}

/// ℓ2-normalize one row in place — elementwise identical to
/// [`Matrix::l2_normalize_rows`] with `eps = 1e-12`, so a key folded
/// incrementally is normalized exactly as the batch path would normalize it.
fn normalize_row(row: &mut [f32]) {
    let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        let inv = 1.0 / norm;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(top_k: usize) -> PreScoreConfig {
        PreScoreConfig { top_k, seed: 7, ..Default::default() }
    }

    fn keys(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(n, d, 1.0, &mut rng)
    }

    #[test]
    fn warmup_is_identity_then_seeds_to_budget() {
        let k = keys(40, 6, 1);
        let mut p = StreamPrescorer::new(cfg(12), 6);
        for i in 0..12 {
            p.fold(k.row(i));
            assert_eq!(p.selection(), (0..=i).collect::<Vec<_>>().as_slice());
        }
        p.fold(k.row(12)); // crosses the budget → seed clustering fires
        assert_eq!(p.selection().len(), 12);
        for i in 13..40 {
            p.fold(k.row(i));
            assert_eq!(p.selection().len(), 12, "selection stays at top_k");
        }
        let sel = p.selection();
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "ascending: {sel:?}");
        assert!(sel.iter().all(|&j| j < 40));
    }

    #[test]
    fn top_k_zero_is_identity_forever() {
        let k = keys(30, 4, 2);
        let mut p = StreamPrescorer::new(cfg(0), 4);
        p.fold_to(&k);
        assert_eq!(p.selection(), (0..30).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn folding_is_prefix_stable() {
        // fold_to in one go ≡ two gos ≡ per-row — bitwise.
        let k = keys(90, 5, 3);
        for method in [Method::KMeans, Method::MiniBatch { batch: 16 }, Method::L2Norm] {
            let c = PreScoreConfig { method, ..cfg(16) };
            let mut a = StreamPrescorer::new(c.clone(), 5);
            a.fold_to(&k);
            let mut b = StreamPrescorer::new(c.clone(), 5);
            b.fold_to(&k.slice_rows(0, 37));
            b.fold_to(&k);
            assert_eq!(a, b, "{method:?}");
        }
    }

    #[test]
    fn seed_clustering_matches_batch_prescore_selection() {
        // At the seed boundary (n = top_k + 1) the streamed state has run
        // exactly the prefill clustering, so its selection must equal batch
        // prescore's over the same keys — pins the shared cluster route
        // (count formula, RNG stream, per-method call) against drift.
        let k = keys(40, 6, 9);
        for method in [Method::KMeans, Method::MiniBatch { batch: 16 }] {
            let c = PreScoreConfig { method, ..cfg(12) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, 13)); // crosses the budget → seeds
            let batch = super::super::prescore(&k.slice_rows(0, 13), &c);
            assert_eq!(p.selection(), batch.selected.as_slice(), "{method:?}");
        }
    }

    #[test]
    fn l2norm_stream_matches_batch_selection() {
        // ℓ2-norm scores are per-key, so the streamed top-k equals batch
        // prescore's selection exactly.
        let k = keys(64, 4, 4);
        let c = PreScoreConfig { method: Method::L2Norm, ..cfg(10) };
        let mut p = StreamPrescorer::new(c.clone(), 4);
        p.fold_to(&k);
        let batch = super::super::prescore(&k, &c);
        assert_eq!(p.selection(), batch.selected.as_slice());
    }

    #[test]
    fn merge_evicts_minimum_only_on_strict_beat() {
        let c = PreScoreConfig { method: Method::L2Norm, ..cfg(2) };
        let mut p = StreamPrescorer::new(c, 1);
        // rows are 1-d; score = x².
        p.fold(&[3.0]); // warmup
        p.fold(&[1.0]); // warmup
        p.fold(&[2.0]); // seeds over {9,1,4} → keep {0,2}
        assert_eq!(p.selection(), &[0, 2]);
        p.fold(&[2.0]); // score 4 == min 4 → tie keeps incumbent
        assert_eq!(p.selection(), &[0, 2]);
        p.fold(&[5.0]); // 25 > 4 → evict pos 2
        assert_eq!(p.selection(), &[0, 4]);
    }

    #[test]
    fn export_restore_roundtrip_all_phases() {
        let k = keys(50, 6, 5);
        for (method, upto) in [
            (Method::KMeans, 8usize),  // warmup phase (top_k=16 below)
            (Method::KMeans, 50),      // clustered phase
            (Method::L2Norm, 50),      // norms phase
        ] {
            let c = PreScoreConfig { method, ..cfg(16) };
            let mut p = StreamPrescorer::new(c.clone(), 6);
            p.fold_to(&k.slice_rows(0, upto));
            let art = p.export();
            let back = StreamPrescorer::restore(c.clone(), 6, p.selection(), &art)
                .expect("restore");
            assert_eq!(back, p, "{method:?} upto {upto}");
            // Restored state keeps folding identically.
            let mut cont = back;
            let mut orig = p;
            cont.fold(&[0.5; 6]);
            orig.fold(&[0.5; 6]);
            assert_eq!(cont, orig);
        }
        // Mismatched selection/scores refuse to restore.
        let c = cfg(4);
        let p = StreamPrescorer::new(c.clone(), 6);
        let art = p.export();
        assert!(StreamPrescorer::restore(c, 6, &[0, 1], &art).is_none());
        // A warmup buffer inconsistent with the fold count is refused at
        // restore time (it would otherwise panic a later seed()).
        let c = cfg(16);
        let mut p = StreamPrescorer::new(c.clone(), 6);
        p.fold_to(&k.slice_rows(0, 4));
        let mut art = p.export();
        art.warmup.truncate(6); // one row left for four folded keys
        assert!(StreamPrescorer::restore(c, 6, p.selection(), &art).is_none());
    }

    #[test]
    #[should_panic(expected = "no streaming fold")]
    fn unsupported_method_panics() {
        StreamPrescorer::new(
            PreScoreConfig { method: Method::KMedian, ..cfg(8) },
            4,
        );
    }
}
