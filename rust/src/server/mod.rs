//! Thread-based serving loop (tokio substitute — see DESIGN.md).
//!
//! A `ScoringServer` owns the dynamic batcher, a pool of executor workers,
//! and — when a trained `weights.bin` is present — a pure-Rust **decode
//! engine**. Clients submit requests over an mpsc channel and receive
//! responses over per-request channels. One coordinator thread blocks on
//! the job queue (`recv_timeout` against the batch deadline — no busy-wait
//! polling), forms batches, and feeds a shared work queue that the executor
//! workers drain; each worker owns its own [`ArtifactRegistry`] because
//! PJRT handles are not `Send`. Python is never on this path.
//!
//! Two request classes flow through the same worker pool:
//!
//! * **Scoring** (`generate == 0`) — dynamic batches executed against the
//!   AOT artifacts (or, when no artifact is loadable but the substrate
//!   model is, scored by the pure-Rust transformer).
//! * **Generation** (`generate > 0`) — routed to the decode engine: one
//!   prefill on the transformer substrate captures per-layer/head KV caches
//!   and attention [`crate::attention::DecodeState`]s, then the
//!   prefill/decode [`Scheduler`] dispatches decode *rounds*
//!   ([`Scheduler::next_round`]) that step each sequence through the
//!   backends' `decode_step` against the block-allocated
//!   [`KvCacheManager`] — prefill is never re-run, so a decode step costs
//!   selection-sized work for `prescored:`/`restricted:` specs instead of
//!   O(n²). Workers re-pump the scheduler after every round, so decode
//!   throughput is not gated on the coordinator's batching deadline, and
//!   the scheduler's starvation bound (observable via
//!   [`ServerStats::decode_rounds`] and the per-step percentiles) keeps
//!   decode latency bounded under prefill pressure. Decode rounds are
//!   **worker-split**: the engine mutex is held only for round assembly
//!   (`prepare_decode`) and result application (`complete_decode`); the
//!   token steps themselves run lock-free, so rounds on different workers
//!   overlap instead of serializing behind one engine mutex. Requests are
//!   scheduled into per-tenant deficit-round-robin lanes
//!   (`Request::tenant`), and [`ScoringServer::submit_streaming`] delivers
//!   each step's token as it lands — the [`crate::gateway`] HTTP/SSE front
//!   door builds on both.
//!
//! Worker count: `ServingConfig::executor_workers`, with 0 meaning "derive
//! from the [`crate::parallel`] pool width" (i.e. `PALLAS_THREADS`), capped
//! so a laptop-sized pool doesn't compile one artifact registry per core.
//!
//! **Fault tolerance.** Every request reaches a terminal state with a typed
//! [`Response`] (never a silently dropped channel): deadlines
//! (`Request::deadline_ms`) and cancellation ([`ScoringServer::cancel`])
//! are observed at the safe points — admission, the prefill→decode
//! boundary, and between decode rounds — and tear down with their KV pages
//! and prefix pins released. Worker panics are caught at the work-item
//! boundary ([`std::panic::catch_unwind`]), fail only the requests in the
//! panicked item with [`crate::coordinator::ServerError::Internal`], and
//! the worker keeps draining the queue. Under pool pressure admission
//! degrades down the [`shed`] ladder instead of rejecting (truthfully
//! reported via `Response::degraded`/`spec`), after first retrying a failed
//! page reservation against budget reclaimed from unpinned prefix-cache
//! subtrees. The [`crate::fault`] hooks make all of it deterministically
//! testable.

pub mod cancel;
pub mod session;
pub mod shed;

use crate::attention::{AttentionBackend, AttentionSpec, AttnPolicy};
use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, PrefixHit, PrefixSnapshot};
use crate::config::ServingConfig;
use crate::coordinator::{
    Batch, BatcherConfig, DynamicBatcher, KvCacheManager, KvDtype, KvStore, PreScoreManager,
    PreScoreManagerConfig, Request, Response, Scheduler, SchedulerConfig, ServerError,
    WorkItem,
};
use crate::fault::FaultPoint;
use crate::linalg::Matrix;
use crate::metrics::LatencyStats;
use crate::model::transformer::{argmax_row, nll_entry, nll_from_logits};
use crate::model::{DecodeSession, Transformer, TransformerConfig, WeightStore};
use crate::parallel;
use crate::runtime::ArtifactRegistry;
use anyhow::Result;
use cancel::{CancelRegistry, CancelToken};
use session::{ResumeError, SessionCounters, SessionHub};
use shed::{build_ladder, LoadShedder, Rung};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Poison-tolerant lock: a worker panic is already accounted (and the
/// request failed with a typed error) at the `catch_unwind` boundary — the
/// shared structures stay serviceable instead of cascading
/// `PoisonError` panics through every other request on the server.
fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// A submitted job: the request plus the channel to answer on.
pub struct Job {
    pub request: Request,
    pub respond: Sender<Response>,
    /// Per-step token stream for [`ScoringServer::submit_streaming`]
    /// clients (`None` = unary submit). Dropped at the terminal response.
    pub stream: Option<Sender<StreamEvent>>,
    /// Resumable session (opened through [`ScoringServer::open_session`]):
    /// tokens and the terminal route through the [`SessionHub`] instead of
    /// the direct channels above.
    pub session: bool,
}

/// One decode step's incremental output, delivered on the event channel of
/// [`ScoringServer::submit_streaming`] as the step lands — before the
/// sequence (or the round) finishes. The terminal [`Response`] still
/// arrives on the response channel and remains the single source of truth
/// for served-spec/degraded/error fields.
#[derive(Debug, Clone)]
pub struct StreamEvent {
    pub id: u64,
    /// Tokens this step produced (currently always one).
    pub tokens: Vec<u32>,
    /// Total tokens generated so far, including `tokens`.
    pub total: usize,
}

/// What [`ScoringServer::resume_session`] hands the gateway: the buffered
/// suffix to replay (sequence-numbered), the live receivers for this
/// attachment, and — when the session already finished — the stored
/// terminal (no live continuation follows; replay and close).
pub struct SessionTicket {
    pub session_id: String,
    /// Buffered `(seq, token)` pairs strictly after the resume cursor.
    pub replay: Vec<(usize, u32)>,
    pub events: Receiver<StreamEvent>,
    pub terminal: Receiver<Response>,
    pub done: Option<Response>,
}

/// Server statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub completed: usize,
    pub batches: usize,
    pub total_lanes: usize,
    pub occupied_lanes: usize,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
    /// Executor workers that drained the work queue.
    pub workers: usize,
    /// Attention kernel the server was configured with
    /// ([`crate::attention::AttnStats::kernel`]).
    pub kernel: String,
    /// Prefill executions (scoring batches + decode-engine prefills).
    pub prefills: usize,
    /// Decode rounds dispatched by the scheduler.
    pub decode_rounds: usize,
    /// Individual decode steps executed across all sequences.
    pub decode_steps: usize,
    /// Per-decode-step wall time percentiles (ms) — the starvation-bound
    /// observability the scheduler's policy is judged by.
    pub decode_step_p50_ms: f64,
    pub decode_step_p99_ms: f64,
    /// Shared-prefix cache accounting (all zero when the cache is disabled
    /// or the spec is not prefix-cacheable). `prefix_hit_tokens` counts
    /// prefill tokens served from the cache — forward/pre-scoring work the
    /// warm path never performed.
    pub prefix_hits: usize,
    pub prefix_misses: usize,
    pub prefix_hit_tokens: usize,
    pub prefix_insertions: usize,
    pub prefix_evictions: usize,
    pub prefix_nodes: usize,
    pub prefix_cached_tokens: usize,
    /// Warm disk tier ([`crate::cache::tier`]): subtrees spilled on
    /// eviction, spilled prefixes re-admitted on a radix hit, and bytes
    /// currently resident in the spill index (all zero without a
    /// `[cache] spill_path`).
    pub tier_spills: usize,
    pub tier_readmits: usize,
    pub tier_bytes: usize,
    /// Requests that reached a terminal state via `ScoringServer::cancel`.
    pub cancelled: usize,
    /// Requests failed because their `deadline_ms` elapsed.
    pub expired: usize,
    /// Completed requests served below the configured spec (down-ladder).
    pub degraded: usize,
    /// Admissions refused outright (`shed_mode = "reject"` under pressure).
    pub shed_rejects: usize,
    /// Requests failed with `ServerError::Internal` (panics, artifact
    /// failures) — the server survived each of them.
    pub internal_errors: usize,
    /// Worker panics caught at the work-item boundary.
    pub worker_panics: usize,
    /// KV page accounting over the server's lifetime. Teardown correctness
    /// invariant (asserted by the chaos/cancellation suites): once the
    /// server drains, `kv_pages_acquired == kv_pages_released` — no faulted,
    /// cancelled, or expired request leaks pool pages.
    pub kv_pages_acquired: usize,
    pub kv_pages_released: usize,
    /// Pages transferred from unpinned prefix-cache subtrees to the KV pool
    /// by the admission retry path.
    pub kv_pages_reclaimed: usize,
    /// Prefix-cache pin accounting (same balance invariant as pages).
    pub prefix_pins_acquired: usize,
    pub prefix_pins_released: usize,
    /// Last observed degradation-ladder rung (0 = full quality).
    pub shed_level: usize,
    /// Tokens produced by decode sessions (streamed to `submit_streaming`
    /// clients as they land), including the partial output of cancelled /
    /// expired / faulted sessions.
    pub streamed_tokens: usize,
    /// Resumable-session lifecycle counters (see [`SessionCounters`]):
    /// entries currently held, cumulative parks, resumes, linger expiries,
    /// drain persists, and restart recoveries.
    pub sessions_live: usize,
    pub sessions_parked: u64,
    pub sessions_resumed: u64,
    pub sessions_expired: u64,
    pub sessions_persisted: u64,
    pub sessions_recovered: u64,
    /// KV-pool headroom for the gateway's readiness probe: free pages and
    /// total pool capacity (both 0 without a decode engine).
    pub kv_free_pages: usize,
    pub kv_capacity_pages: usize,
    /// Realized key-budget distribution over completed requests: each
    /// request contributes the mean retained-key count across its layer·head
    /// selection states. Fixed budgets realize their `top_k`; `mass=`
    /// budgets realize whatever the score distribution demanded, so these
    /// are the observable half of [`crate::prescore::KeyBudget`]. All zero
    /// for non-selecting kernels.
    pub realized_keys_mean: f64,
    pub realized_keys_p50: f64,
    pub realized_keys_p99: f64,
    /// Admissions served at each degradation-ladder rung (index = rung,
    /// 0 = full quality) — per-rung occupancy alongside the instantaneous
    /// `shed_level`.
    pub rung_served: Vec<usize>,
    /// Per-tenant terminal accounting, sorted by tenant key. Balance
    /// invariant: Σ tenants.requests == completed + cancelled + expired +
    /// shed_rejects + internal_errors (Invalid/Unsupported refusals are
    /// counted on neither side).
    pub tenants: Vec<TenantStats>,
}

/// Per-tenant slice of the terminal counters (the gateway's fairness and
/// accounting surface; the empty key is the anonymous tenant).
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    pub tenant: String,
    /// Requests that reached a terminal state for this tenant.
    pub requests: usize,
    /// Generated tokens streamed for this tenant (partial output included).
    pub streamed_tokens: usize,
    /// Capacity refusals (shed rejects + quota rejections surfaced as
    /// `ServerError::Capacity`).
    pub sheds: usize,
    /// Terminal cancellations.
    pub cancels: usize,
}

/// Mutable counters shared between the executor workers.
#[derive(Default)]
struct SharedStats {
    latency: LatencyStats,
    decode_step_latency: LatencyStats,
    completed: usize,
    batches: usize,
    total_lanes: usize,
    occupied_lanes: usize,
    scored_tokens: usize,
    prefills: usize,
    decode_rounds: usize,
    decode_steps: usize,
    cancelled: usize,
    expired: usize,
    degraded: usize,
    shed_rejects: usize,
    internal_errors: usize,
    worker_panics: usize,
    kv_pages_reclaimed: usize,
    shed_level: usize,
    streamed_tokens: usize,
    realized_keys: LatencyStats,
    rung_served: Vec<usize>,
    tenants: HashMap<String, TenantCounters>,
}

/// Mutable per-tenant counters behind `SharedStats.tenants` (exported as
/// [`TenantStats`] in snapshots).
#[derive(Debug, Clone, Default)]
struct TenantCounters {
    requests: usize,
    streamed_tokens: usize,
    sheds: usize,
    cancels: usize,
}

impl SharedStats {
    fn tenant_mut(&mut self, tenant: &str) -> &mut TenantCounters {
        self.tenants.entry(tenant.to_string()).or_default()
    }

    /// Account a terminal failure by class, globally and on the tenant's
    /// slice (success accounting stays at the call sites, which also record
    /// latency/tokens). Every arm that bumps a global terminal counter also
    /// bumps the tenant's `requests` — the balance invariant on
    /// [`ServerStats::tenants`] depends on it.
    fn record_failure(&mut self, tenant: &str, err: &ServerError) {
        match err {
            ServerError::Cancelled => {
                self.cancelled += 1;
                let t = self.tenant_mut(tenant);
                t.requests += 1;
                t.cancels += 1;
            }
            ServerError::DeadlineExceeded => {
                self.expired += 1;
                self.tenant_mut(tenant).requests += 1;
            }
            ServerError::Capacity(_) => {
                self.shed_rejects += 1;
                let t = self.tenant_mut(tenant);
                t.requests += 1;
                t.sheds += 1;
            }
            ServerError::Internal(_) => {
                self.internal_errors += 1;
                self.tenant_mut(tenant).requests += 1;
            }
            ServerError::Invalid(_) | ServerError::Unsupported(_) => {}
        }
    }
}

/// Work drained by the executor pool.
enum Work {
    /// Artifact-scored batch with the responders for its requests (aligned
    /// with `batch.requests`; `None` if a responder was lost, e.g. a
    /// duplicate request id overwrote it — the batch still executes).
    Score { batch: Batch, responders: Vec<Option<Sender<Response>>> },
    /// A prefill/decode round from the decode engine's scheduler.
    Gen(WorkItem),
}

/// Shared work queue (in-process channel) feeding the executor workers.
/// Workers both consume from and (for decode-round re-pumping) produce into
/// it, so it is a mutex/condvar queue rather than an mpsc channel — close()
/// plus an emptiness/engine-idle predicate replaces sender counting.
struct WorkQueue {
    state: Mutex<(VecDeque<Work>, bool)>,
    cv: Condvar,
}

impl WorkQueue {
    fn new() -> WorkQueue {
        WorkQueue { state: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() }
    }

    fn push(&self, w: Work) {
        let mut g = plock(&self.state);
        g.0.push_back(w);
        self.cv.notify_one();
    }

    fn close(&self) {
        let mut g = plock(&self.state);
        g.1 = true;
        self.cv.notify_all();
    }

    /// Blocking pop. Returns `None` once the queue is closed, empty, and
    /// `drained()` reports no in-flight engine work (a finishing decode
    /// round may still re-pump new items after close). `drained()` takes
    /// the engine mutex, so it is evaluated *outside* the queue lock —
    /// pushes never stall behind it.
    fn pop<F: Fn() -> bool>(&self, drained: F) -> Option<Work> {
        loop {
            let closed = {
                let mut g = plock(&self.state);
                loop {
                    if let Some(w) = g.0.pop_front() {
                        return Some(w);
                    }
                    if g.1 {
                        break true;
                    }
                    let (ng, _) = self
                        .cv
                        .wait_timeout(g, Duration::from_millis(25))
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    g = ng;
                }
            };
            debug_assert!(closed);
            if drained() {
                // Re-check under the lock: a decode round finishing between
                // the checks may have re-pumped one last item.
                let g = plock(&self.state);
                if g.0.is_empty() {
                    return None;
                }
                continue;
            }
            // Closed but engine still streaming: pace the re-check.
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// One live generation sequence inside the decode engine.
struct GenSession {
    sess: DecodeSession,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    /// Prefill NLL (scored from the prefill logits — no extra forward).
    nll: Vec<f32>,
    target_new: usize,
    generated: Vec<u32>,
    next_token: u32,
    decode_ms: f64,
    /// Pinned prefix-cache node this session branched from (released on
    /// finish so LRU eviction can reclaim cold prefixes).
    cache_pin: Option<usize>,
    /// Checked between decode rounds (a safe point): a tripped token ends
    /// the session with `ServerError::Cancelled` and releases its pages.
    cancel: CancelToken,
    /// Absolute deadline, if the request set one.
    deadline: Option<Instant>,
    /// Degradation-ladder rung this session was admitted at (0 = full).
    rung: usize,
    /// The rung's policy — decode steps run under the spec the request was
    /// truthfully admitted at, not necessarily the configured one.
    policy: Arc<AttnPolicy>,
    /// Per-step token stream (`submit_streaming`); dropped with the session
    /// at conclude, which disconnects the event channel.
    stream: Option<Sender<StreamEvent>>,
    /// Resumable sessions emit through the hub (sequence-numbered, with a
    /// replay buffer) instead of the direct `stream` channel.
    hub: Option<Arc<SessionHub>>,
    /// Fairness/accounting key from the request (empty = anonymous).
    tenant: String,
    /// Scheduler lane (stable per tenant) this session decodes in.
    lane: usize,
}

/// Teardown bookkeeping for a request computing outside the engine lock
/// (a prefill forward, or a decode step checked out of `sessions`): enough
/// to answer the client and release every resource if the request is
/// cancelled, expires, or its worker panics mid-compute.
struct InFlightInfo {
    respond: Option<Sender<Response>>,
    arrived: Instant,
    /// Prefix-cache node pinned by the admission-time lookup.
    pin: Option<usize>,
    rung: usize,
    cancel: CancelToken,
    deadline: Option<Instant>,
    /// Event stream to hand to the session once the prefill installs
    /// (`None` for checked-out decode steps — the session carries its own).
    stream: Option<Sender<StreamEvent>>,
    /// Routes this request's tokens/terminal through the [`SessionHub`].
    session: bool,
    tenant: String,
    lane: usize,
}

/// Everything a prefill needs, cloned out of the engine under its lock so
/// the (long) forward runs lock-free: the immutable model/policy handles,
/// the request, and the prefix-cache hit if any.
struct PrefillPrep {
    id: u64,
    tokens: Vec<u32>,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    generate: usize,
    hit: Option<PrefixHit>,
    model: Arc<Transformer>,
    policy: Arc<AttnPolicy>,
    /// Snapshot the (extended) prefix into the cache afterwards?
    want_snapshot: bool,
    /// Storage grid for captured/snapshotted KV rows.
    kv_dtype: KvDtype,
}

/// Result of the lock-free prefill compute, applied back under the lock.
struct PrefillOutcome {
    id: u64,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    generate: usize,
    result: Result<PrefillDone>,
}

struct PrefillDone {
    sess: DecodeSession,
    nll: Vec<f32>,
    next_token: u32,
    snapshot: Option<(Vec<u32>, PrefixSnapshot)>,
    /// Pinned cache node of the warm hit this prefill branched from.
    cache_pin: Option<usize>,
}

/// A decode step checked out of the engine for lock-free compute: the
/// session itself plus the immutable model handle. While a step is out,
/// `DecodeEngine::checked_out` keeps the teardown bookkeeping.
struct DecodeStep {
    id: u64,
    sess: GenSession,
    model: Arc<Transformer>,
    /// Mirror the refreshed selections into the KV manager afterwards?
    refresh: bool,
}

/// What one lock-free decode step produced.
struct StepCompute {
    finished: bool,
    /// Step wall time (`None` only on the unreachable empty-slot guard).
    step_ms: Option<f64>,
    refresh_snap: Option<Vec<Vec<usize>>>,
}

enum StepResult {
    Stepped(StepCompute),
    Panicked,
}

/// Phase-2 result handed back to `complete_decode`. The session survives
/// even a panicked step, so the terminal response still reports its
/// partial tokens.
struct DecodeStepDone {
    id: u64,
    sess: Option<GenSession>,
    result: StepResult,
}

/// Pure-Rust decode engine: prefill once on the transformer substrate, then
/// stream tokens through the attention backends' `decode_step` against the
/// block-allocated KV cache. The engine is a single mutex-guarded state
/// machine (sessions step sequentially within a round); the decode kernels
/// themselves shard across the persistent [`crate::parallel`] pool.
struct DecodeEngine {
    /// Immutable model/policy behind `Arc` so prefills and substrate scoring
    /// clone a handle out of a brief lock and run the forward lock-free —
    /// a long scoring forward can no longer stall decode rounds.
    model: Arc<Transformer>,
    policy: Arc<AttnPolicy>,
    manager: PreScoreManager,
    kv: KvCacheManager,
    scheduler: Scheduler,
    /// Shared-prefix cache (None when disabled or the spec's artifacts are
    /// not prefix-reusable).
    cache: Option<PrefixCache>,
    /// Partial-prefix hits allowed? Only for suffix-stable kernels
    /// (exact/flash, and `prescored:...,mode=stream` whose streaming
    /// selection makes prefix rows length-invariant); the remaining
    /// rank/selection kernels dedup at full length only — see
    /// `AttentionSpec::suffix_stable`.
    suffix_stable: bool,
    /// Storage grid for session/cache KV rows (`[cache] kv_dtype`): f16 and
    /// int8 snap captured rows via fake-quant mirrors and pack cached pages
    /// 2×/4× denser; f32 keeps the bitwise legacy behavior.
    kv_dtype: KvDtype,
    /// Admitted but not yet prefilled.
    pending: HashMap<u64, Job>,
    /// Requests whose prefill is computing outside the lock, with the
    /// bookkeeping to tear them down from any thread. Keeps `active()`
    /// truthful for the shutdown drain AND guards the duplicate check: a
    /// re-submitted id must not reach `kv.admit` (which asserts single
    /// admission) while the first prefill is mid-flight.
    in_flight: HashMap<u64, InFlightInfo>,
    /// Prefilled, streaming tokens.
    sessions: HashMap<u64, GenSession>,
    kernel: &'static str,
    /// The degradation ladder (rung 0 = the configured spec at full
    /// budget) and the watermark tracker that picks the admission rung.
    rungs: Vec<Rung>,
    shedder: LoadShedder,
    /// `shed_mode = "reject"`: refuse over-capacity admissions with
    /// `ServerError::Capacity` instead of requeueing/degrading.
    shed_reject: bool,
    /// Shared request-id → cancel-token map (the server handle trips the
    /// tokens; the engine observes them at safe points).
    cancels: Arc<CancelRegistry>,
    /// Ids whose admission already took one injected `KvAdmit` fault — the
    /// fault fires once per request so the reclaim-retry path is exercised
    /// without livelocking the requeue loop.
    faulted_admits: std::collections::HashSet<u64>,
    /// Sessions checked out of `sessions` while their decode step computes
    /// outside the engine lock (the worker-split path): enough bookkeeping
    /// to tear one down from `fail_request` if its worker dies mid-step,
    /// and what keeps `active()` truthful while the maps are empty.
    checked_out: HashMap<u64, InFlightInfo>,
    /// Tenant key → scheduler lane index (first-seen order; the DRR lanes
    /// give each tenant a fair share of prefill and decode dispatch).
    tenant_lanes: HashMap<String, usize>,
    /// Resumable-session registry shared with the server handle and the
    /// gateway. The engine emits/finishes through it; parked sessions step
    /// out of `sessions` into `parked` (pages stay pinned) until a resume
    /// wakes them or the linger expiry reclaims them.
    hub: Arc<SessionHub>,
    /// Sessions paused because their client vanished: removed from decode
    /// scheduling but holding their KV pages and prefix pin, keyed by
    /// engine request id. `active()` counts them — a parked session is
    /// in-flight work until it resumes, expires, or the drain persists it.
    parked: HashMap<u64, GenSession>,
}

impl DecodeEngine {
    fn new(
        model: Transformer,
        cfg: &ServingConfig,
        spec: &AttentionSpec,
        cancels: Arc<CancelRegistry>,
        hub: Arc<SessionHub>,
    ) -> DecodeEngine {
        let mut manager_cfg = PreScoreManagerConfig::from_serving(cfg).unwrap_or_else(|e| {
            // A bad [prescore] method must not silently change the decode
            // refresh cadence — keep the configured period on fallback.
            eprintln!("decode engine: {e:#}; using default prescore policy");
            PreScoreManagerConfig {
                refresh_every: cfg.prescore_refresh_every,
                ..Default::default()
            }
        });
        // One refresh policy end to end: selection-cached specs own their
        // period (`prescored:` via `refresh=` / the legacy-key derivation,
        // `restricted:` via its `refresh=` key); the legacy
        // `[prescore] refresh_every` only applies to specs without one. The
        // manager drives both the states (set_refresh_every at prefill) and
        // the KV-cache selection-mirror cadence, so they can never drift.
        match spec {
            AttentionSpec::PreScored(ps) => {
                manager_cfg.refresh_every = ps.decode_refresh_every;
                manager_cfg.budget = ps.prescore.budget;
                manager_cfg.fallback_delta = ps.fallback_delta;
            }
            AttentionSpec::Restricted { refresh, .. }
                if *refresh != crate::attention::decode::RESTRICTED_REFRESH_DEFAULT =>
            {
                // Previously set_refresh_every stomped the spec's period
                // with the legacy key at prefill — the serving half of the
                // "refresh unreachable from the restricted grammar" bug.
                // Only a non-default `refresh=` wins: an omitted key is
                // indistinguishable from the default, and existing configs
                // that steer restricted cadence via `[prescore]
                // refresh_every` must keep working.
                manager_cfg.refresh_every = *refresh;
            }
            _ => {}
        }
        let slots = model.cfg.n_layers * model.cfg.n_heads;
        let model = Arc::new(model);
        let policy = Arc::new(AttnPolicy::uniform(spec.clone()));
        // `ServingConfig` validates the dtype string eagerly, so this parse
        // only falls back for hand-built configs that skipped validation.
        let kv_dtype = KvDtype::parse(&cfg.kv_dtype).unwrap_or_else(|e| {
            eprintln!("decode engine: {e:#}; storing KV as f32");
            KvDtype::F32
        });
        let cache = if cfg.prefix_cache_blocks > 0 && spec.prefix_cacheable() {
            let persist_path = if cfg.prefix_persist_path.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.prefix_persist_path))
            };
            let spill_path = if cfg.prefix_spill_path.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.prefix_spill_path))
            };
            let mut cache = PrefixCache::new(PrefixCacheConfig {
                blocks: cfg.prefix_cache_blocks,
                min_tokens: cfg.prefix_min_tokens,
                persist_path,
                kv_dtype,
                spill_path,
            });
            cache.set_restorer(Arc::clone(&policy), model.cfg.n_heads);
            if let Some(p) = cache.config().persist_path.clone() {
                if p.exists() {
                    match crate::cache::persist::load(
                        &mut cache,
                        &policy,
                        model.cfg.n_heads,
                        slots,
                        model.cfg.d_head(),
                        model.cfg.vocab,
                        &p,
                    ) {
                        Ok((n, sessions)) => {
                            let ns = sessions.len();
                            hub.restore(sessions);
                            eprintln!(
                                "prefix cache: restored {n} prefixes and {ns} parked \
                                 sessions from {}",
                                p.display()
                            );
                        }
                        Err(e) => eprintln!(
                            "prefix cache: ignoring {}: {e:#}",
                            p.display()
                        ),
                    }
                }
            }
            Some(cache)
        } else {
            if cfg.prefix_cache_blocks > 0 {
                eprintln!(
                    "prefix cache disabled: spec '{spec}' has no prefix-reusable artifacts"
                );
            }
            None
        };
        let rungs =
            build_ladder(spec, cfg.decode_max_new, manager_cfg.refresh_every, cfg.shed_min_top_k);
        let shedder = LoadShedder::new(
            cfg.shed_high_watermark,
            cfg.shed_low_watermark,
            cfg.shed_queue_high,
            cfg.shed_queue_low,
            rungs.len().saturating_sub(1),
            cfg.shed_pin_rung,
        );
        DecodeEngine {
            kv: KvCacheManager::new(cfg.kv_blocks, slots),
            manager: PreScoreManager::new(manager_cfg),
            scheduler: Scheduler::new(SchedulerConfig::default()),
            policy,
            cache,
            suffix_stable: spec.suffix_stable(),
            kv_dtype,
            pending: HashMap::new(),
            in_flight: HashMap::new(),
            sessions: HashMap::new(),
            kernel: spec.kernel_name(),
            model,
            rungs,
            shedder,
            shed_reject: cfg.shed_mode == "reject",
            cancels,
            faulted_admits: std::collections::HashSet::new(),
            checked_out: HashMap::new(),
            tenant_lanes: HashMap::new(),
            hub,
            parked: HashMap::new(),
        }
    }

    /// Anything admitted, mid-prefill, streaming, or checked out for a
    /// lock-free decode step (work may still be in flight even when the
    /// scheduler queues are momentarily empty).
    fn active(&self) -> bool {
        !self.pending.is_empty()
            || !self.in_flight.is_empty()
            || !self.sessions.is_empty()
            || !self.checked_out.is_empty()
            || !self.parked.is_empty()
    }

    /// Stable scheduler lane for a tenant key (created on first sight).
    fn lane_for(&mut self, tenant: &str) -> usize {
        if let Some(&lane) = self.tenant_lanes.get(tenant) {
            return lane;
        }
        let lane = self.tenant_lanes.len();
        self.tenant_lanes.insert(tenant.to_string(), lane);
        lane
    }

    fn admit(&mut self, job: Job) {
        let id = job.request.id;
        let lane = self.lane_for(&job.request.tenant);
        self.pending.insert(id, job);
        self.scheduler.submit_prefill_for(lane, vec![id]);
    }

    fn next_round(&mut self, free_workers: usize) -> Vec<WorkItem> {
        self.scheduler.next_round(free_workers)
    }

    /// Per-layer·head selections snapshot for the KV-cache manager.
    fn selections_snapshot(sess: &DecodeSession) -> Vec<Vec<usize>> {
        sess.states()
            .iter()
            .map(|s| s.selection().map(|x| x.to_vec()).unwrap_or_default())
            .collect()
    }

    /// Fail `id` at admission time: drop its cancel-token entry, account
    /// the failure class, and answer the client with a typed response.
    fn refuse(
        &mut self,
        id: u64,
        respond: Sender<Response>,
        arrived: Instant,
        tenant: &str,
        err: ServerError,
        shared: &Mutex<SharedStats>,
    ) {
        self.cancels.remove(id);
        plock(shared).record_failure(tenant, &err);
        let resp =
            Response::failure(id, ms_since(arrived), self.rungs[0].spec_str.clone(), err);
        // Session requests answer through the hub (which owns exactly-once
        // terminal delivery); everyone else on the direct channel.
        if !self.hub.finish(id, &resp) {
            let _ = respond.send(resp);
        }
    }

    /// Phase 1 of a prefill, under the engine lock: admission checks (the
    /// first cancellation/deadline safe point), the shedding decision, KV
    /// page reservation with one reclaim-retry, and the prefix-cache walk.
    /// Returns the lock-free compute's input (`None` = answered with a
    /// typed failure, duplicate, or requeued).
    fn prepare_prefill(&mut self, id: u64, shared: &Mutex<SharedStats>) -> Option<PrefillPrep> {
        let job = self.pending.remove(&id)?;
        if self.sessions.contains_key(&id) || self.in_flight.contains_key(&id) {
            // Duplicate request id while the first is still streaming (or
            // still computing its prefill outside the lock): the newer
            // responder is dropped (same policy as the scoring path's
            // responder map). The in-flight check matters because
            // `kv.admit` asserts single admission.
            return None;
        }
        let arrived = job.request.arrived;
        let cancel = self.cancels.register(id);
        if cancel.is_cancelled() {
            let Job { request, respond, .. } = job;
            self.refuse(id, respond, arrived, &request.tenant, ServerError::Cancelled, shared);
            return None;
        }
        if job.request.expired() {
            let Job { request, respond, .. } = job;
            let err = ServerError::DeadlineExceeded;
            self.refuse(id, respond, arrived, &request.tenant, err, shared);
            return None;
        }
        let mut tokens = job.request.tokens.clone();
        tokens.truncate(self.model.cfg.max_seq);
        if tokens.is_empty() {
            let Job { request, respond, .. } = job;
            let err = ServerError::Invalid("empty token stream".into());
            self.refuse(id, respond, arrived, &request.tenant, err, shared);
            return None;
        }
        // Shedding decision: fold pool occupancy + queue depth into the
        // ladder position this request is admitted at.
        let cap = self.kv.capacity();
        let occupancy = 1.0 - self.kv.free_blocks() as f64 / cap.max(1) as f64;
        let rung = self.shedder.observe(occupancy, self.pending.len() + 1);
        {
            let mut st = plock(shared);
            st.shed_level = rung;
            if st.rung_served.len() <= rung {
                st.rung_served.resize(rung + 1, 0);
            }
            st.rung_served[rung] += 1;
        }
        let need_pages = crate::coordinator::kv_cache::pages_for(tokens.len());
        if need_pages > cap {
            let Job { request, respond, .. } = job;
            let err = ServerError::Capacity(format!(
                "request needs {need_pages} kv pages but the pool holds {cap}"
            ));
            self.refuse(id, respond, arrived, &request.tenant, err, shared);
            return None;
        }
        // Injected `KvAdmit` fault: pretend the reservation failed so the
        // reclaim-retry path below runs — at most once per id, so the
        // requeue loop cannot livelock on a deterministically-refiring
        // fault.
        let fault_admit =
            crate::fault::fires(FaultPoint::KvAdmit, id) && self.faulted_admits.insert(id);
        let mut admitted = if fault_admit { None } else { self.kv.admit(id, tokens.len()) };
        if admitted.is_none() {
            // Before shedding, retry once against budget reclaimed from
            // unpinned prefix-cache subtrees (LRU victims first).
            let freed = self.cache.as_mut().map_or(0, |c| c.shed_pages(need_pages));
            if freed > 0 {
                self.kv.grow(freed);
                plock(shared).kv_pages_reclaimed += freed;
            }
            admitted = self.kv.admit(id, tokens.len());
        }
        if admitted.is_none() {
            if self.shed_reject {
                let Job { request, respond, .. } = job;
                let err = ServerError::Capacity("kv page pool exhausted".into());
                self.refuse(id, respond, arrived, &request.tenant, err, shared);
            } else {
                // Degrade mode: requeue — pages free as sequences finish,
                // the scheduler's prefill-priority keeps retrying at the
                // pump cadence, and the next attempt re-observes the
                // shedder (likely landing on a deeper rung).
                let lane = self.lane_for(&job.request.tenant);
                self.pending.insert(id, job);
                self.scheduler.submit_prefill_for(lane, vec![id]);
            }
            return None;
        }
        // Walk the shared-prefix tree; a hit clones the cached KV/artifacts
        // out (copy-on-write branch) and pins the node until conclude().
        // Non-suffix-stable kernels only dedup full-length matches. Rung 0
        // only: cached artifacts were computed under the base policy, and a
        // degraded request runs a different one.
        let full_only = !self.suffix_stable;
        let hit = if rung == 0 {
            self.cache.as_mut().and_then(|c| c.lookup(&tokens, full_only))
        } else {
            None
        };
        let cached = hit.as_ref().map_or(0, |h| h.len);
        let want_snapshot = rung == 0
            && self
                .cache
                .as_ref()
                .map_or(false, |c| c.wants_insert(&tokens, cached, full_only));
        let lane = self.lane_for(&job.request.tenant);
        let Job { request, respond, stream, session } = job;
        self.in_flight.insert(
            id,
            InFlightInfo {
                respond: Some(respond.clone()),
                arrived,
                pin: hit.as_ref().map(|h| h.node),
                rung,
                cancel,
                deadline: request.deadline(),
                stream,
                session,
                tenant: request.tenant.clone(),
                lane,
            },
        );
        Some(PrefillPrep {
            id,
            tokens,
            respond: Some(respond),
            arrived,
            generate: request.generate,
            hit,
            model: Arc::clone(&self.model),
            policy: Arc::clone(&self.rungs[rung].policy),
            want_snapshot,
            kv_dtype: self.kv_dtype,
        })
    }

    /// Phase 3, back under the lock: observe the prefill→decode safe point
    /// (cancellation/deadline verdicts tear down here with every resource
    /// released), then install the session, mirror the selections into the
    /// KV manager, and snapshot the prefix into the cache.
    fn complete_prefill(&mut self, outcome: PrefillOutcome, shared: &Mutex<SharedStats>) {
        let PrefillOutcome { id, respond, arrived, generate, result } = outcome;
        let Some(info) = self.in_flight.remove(&id) else { return };
        match result {
            Ok(done) => {
                let PrefillDone { mut sess, nll, next_token, snapshot, cache_pin } = done;
                let verdict = if info.cancel.is_cancelled() {
                    Some(ServerError::Cancelled)
                } else if info.deadline.map_or(false, |d| Instant::now() >= d) {
                    Some(ServerError::DeadlineExceeded)
                } else {
                    None
                };
                if let Some(err) = verdict {
                    self.kv.evict(id);
                    if let (Some(pin), Some(cache)) = (cache_pin, self.cache.as_mut()) {
                        cache.release(pin);
                    }
                    self.cancels.remove(id);
                    self.faulted_admits.remove(&id);
                    plock(shared).record_failure(&info.tenant, &err);
                    let resp = Response::failure(
                        id,
                        ms_since(arrived),
                        self.rungs[info.rung].spec_str.clone(),
                        err,
                    );
                    if !self.hub.finish(id, &resp) {
                        if let Some(tx) = respond {
                            let _ = tx.send(resp);
                        }
                    }
                    return;
                }
                sess.set_refresh_every(self.rungs[info.rung].refresh_every);
                let unique_chain = !self.suffix_stable;
                if let (Some(cache), Some((tokens, snap))) = (self.cache.as_mut(), snapshot) {
                    cache.insert(&tokens, snap, unique_chain);
                }
                self.kv.set_selections(id, Self::selections_snapshot(&sess));
                plock(shared).prefills += 1;
                let lane = info.lane;
                let hub = info.session.then(|| Arc::clone(&self.hub));
                self.sessions.insert(
                    id,
                    GenSession {
                        sess,
                        respond,
                        arrived,
                        nll,
                        target_new: generate.min(self.rungs[info.rung].max_new),
                        generated: Vec::new(),
                        next_token,
                        decode_ms: 0.0,
                        cache_pin,
                        cancel: info.cancel,
                        deadline: info.deadline,
                        rung: info.rung,
                        policy: Arc::clone(&self.rungs[info.rung].policy),
                        stream: info.stream,
                        hub,
                        tenant: info.tenant,
                        lane,
                    },
                );
                if self.hub.park_requested(id) {
                    // The client vanished during the prefill: pause before
                    // the first decode step, pages pinned, resumable.
                    if let Some(s) = self.sessions.remove(&id) {
                        self.parked.insert(id, s);
                    }
                } else {
                    self.scheduler.submit_decode_for(lane, id);
                }
            }
            Err(e) => {
                self.kv.evict(id);
                if let (Some(pin), Some(cache)) = (info.pin, self.cache.as_mut()) {
                    cache.release(pin);
                }
                self.cancels.remove(id);
                self.faulted_admits.remove(&id);
                let err = ServerError::Internal(format!("prefill failed: {e:#}"));
                plock(shared).record_failure(&info.tenant, &err);
                let resp = Response::failure(
                    id,
                    ms_since(arrived),
                    self.rungs[info.rung].spec_str.clone(),
                    err,
                );
                if !self.hub.finish(id, &resp) {
                    if let Some(tx) = respond {
                        let _ = tx.send(resp);
                    }
                }
            }
        }
    }

    /// Force `id` — whatever its phase — to a terminal `Internal` failure:
    /// the recovery path after a worker panic is caught mid-item. Called
    /// with the engine lock held; locks `shared` inside (engine → shared is
    /// the lock order everywhere).
    fn fail_request(&mut self, id: u64, shared: &Mutex<SharedStats>) {
        if self.sessions.contains_key(&id) || self.parked.contains_key(&id) {
            let err = ServerError::Internal("decode worker panicked".into());
            self.conclude(id, Some(err), shared);
            return;
        }
        // Checked out for a lock-free decode step when the worker died: the
        // session itself is gone with the worker's stack, but the teardown
        // bookkeeping (responder clone, pin, rung) survives here.
        if let Some(info) = self.checked_out.remove(&id) {
            self.kv.evict(id);
            if let (Some(pin), Some(cache)) = (info.pin, self.cache.as_mut()) {
                cache.release(pin);
            }
            self.cancels.remove(id);
            self.faulted_admits.remove(&id);
            let err = ServerError::Internal("decode worker panicked".into());
            plock(shared).record_failure(&info.tenant, &err);
            let resp = Response::failure(
                id,
                ms_since(info.arrived),
                self.rungs[info.rung].spec_str.clone(),
                err,
            );
            if !self.hub.finish(id, &resp) {
                if let Some(tx) = info.respond {
                    let _ = tx.send(resp);
                }
            }
            return;
        }
        if let Some(info) = self.in_flight.remove(&id) {
            self.kv.evict(id);
            if let (Some(pin), Some(cache)) = (info.pin, self.cache.as_mut()) {
                cache.release(pin);
            }
            self.cancels.remove(id);
            self.faulted_admits.remove(&id);
            let err = ServerError::Internal("prefill worker panicked".into());
            plock(shared).record_failure(&info.tenant, &err);
            let resp = Response::failure(
                id,
                ms_since(info.arrived),
                self.rungs[info.rung].spec_str.clone(),
                err,
            );
            if !self.hub.finish(id, &resp) {
                if let Some(tx) = info.respond {
                    let _ = tx.send(resp);
                }
            }
            return;
        }
        if let Some(job) = self.pending.remove(&id) {
            self.cancels.remove(id);
            let err = ServerError::Internal("worker panicked before prefill".into());
            plock(shared).record_failure(&job.request.tenant, &err);
            let resp = Response::failure(
                id,
                ms_since(job.request.arrived),
                self.rungs[0].spec_str.clone(),
                err,
            );
            if !self.hub.finish(id, &resp) {
                let _ = job.respond.send(resp);
            }
        }
        // Unknown id: already terminal (e.g. concluded inside the panicked
        // round before the panic) — nothing to release.
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Persist the artifact store on shutdown (no-op without a path).
    fn save_cache(&self) {
        let Some(cache) = self.cache.as_ref() else { return };
        let Some(path) = cache.config().persist_path.clone() else { return };
        // Non-suffix-stable policies must not persist mixed-donor chains
        // (lookup refuses them; a reload would launder the mix).
        let uniform_only = !self.suffix_stable;
        if let Err(e) = crate::cache::persist::save(
            cache,
            &self.policy,
            self.model.cfg.n_heads,
            uniform_only,
            &self.hub.records(),
            &path,
        ) {
            eprintln!("prefix cache persist failed: {e:#}");
        }
    }

    /// Phase 1 of a decode round, under the engine lock: observe the
    /// between-rounds safe point (cancellation/deadline verdicts conclude
    /// here with every resource released), reserve each survivor's next KV
    /// slot, and check the sessions out for lock-free compute — the lock is
    /// held only for this round assembly, so rounds on different workers
    /// overlap in the compute phase. Checked-out ids park their teardown
    /// bookkeeping in `checked_out` (see `fail_request`).
    fn prepare_decode(&mut self, ids: &[u64], shared: &Mutex<SharedStats>) -> Vec<DecodeStep> {
        let max_seq = self.model.cfg.max_seq;
        let mut steps = Vec::with_capacity(ids.len());
        for &id in ids {
            let verdict = match self.sessions.get(&id) {
                None => continue,
                Some(s) if s.cancel.is_cancelled() => Some(ServerError::Cancelled),
                Some(s) if s.deadline.map_or(false, |d| Instant::now() >= d) => {
                    Some(ServerError::DeadlineExceeded)
                }
                Some(_) => None,
            };
            if let Some(err) = verdict {
                self.conclude(id, Some(err), shared);
                continue;
            }
            let Some(s) = self.sessions.get(&id) else { continue };
            if s.generated.len() >= s.target_new || s.sess.pos() >= max_seq {
                self.conclude(id, None, shared);
                continue;
            }
            if s.hub.is_some() && self.hub.park_requested(id) {
                // Client vanished: pause this session at the between-rounds
                // safe point — no KV append, no step, pages stay pinned —
                // until a resume wakes it or the linger expiry reclaims it.
                if let Some(s) = self.sessions.remove(&id) {
                    self.parked.insert(id, s);
                }
                continue;
            }
            if self.kv.append_token(id).is_none() {
                eprintln!("kv cache exhausted for sequence {id}; finishing early");
                self.conclude(id, None, shared);
                continue;
            }
            // Same counter state as the pre-split engine: append has run,
            // the step has not — the refresh lands with this step's result.
            let refresh = self.manager.needs_refresh(self.kv.steps_since_refresh(id));
            let Some(sess) = self.sessions.remove(&id) else { continue };
            self.checked_out.insert(
                id,
                InFlightInfo {
                    respond: sess.respond.clone(),
                    arrived: sess.arrived,
                    pin: sess.cache_pin,
                    rung: sess.rung,
                    cancel: sess.cancel.clone(),
                    deadline: sess.deadline,
                    stream: None,
                    session: sess.hub.is_some(),
                    tenant: sess.tenant.clone(),
                    lane: sess.lane,
                },
            );
            steps.push(DecodeStep { id, sess, model: Arc::clone(&self.model), refresh });
        }
        steps
    }

    /// Phase 3, back under the lock: reinstall the sessions, mirror any
    /// refreshed selections into the KV manager, conclude finished and
    /// panicked sequences, and reschedule the rest into their tenant lanes.
    fn complete_decode(&mut self, done: Vec<DecodeStepDone>, shared: &Mutex<SharedStats>) {
        let mut step_ms: Vec<f64> = Vec::with_capacity(done.len());
        for d in done {
            self.checked_out.remove(&d.id);
            let Some(sess) = d.sess else { continue };
            let lane = sess.lane;
            self.sessions.insert(d.id, sess);
            match d.result {
                StepResult::Stepped(c) => {
                    if let Some(snap) = c.refresh_snap {
                        self.kv.set_selections(d.id, snap);
                    }
                    if let Some(ms) = c.step_ms {
                        step_ms.push(ms);
                    }
                    if c.finished {
                        self.conclude(d.id, None, shared);
                    } else if self
                        .sessions
                        .get(&d.id)
                        .map_or(false, |s| s.hub.is_some())
                        && self.hub.park_requested(d.id)
                    {
                        // Pause instead of rescheduling: the step that just
                        // landed is buffered in the hub for replay.
                        if let Some(s) = self.sessions.remove(&d.id) {
                            self.parked.insert(d.id, s);
                        }
                    } else {
                        self.scheduler.submit_decode_for(lane, d.id);
                    }
                }
                StepResult::Panicked => {
                    plock(shared).worker_panics += 1;
                    let err = ServerError::Internal("decode step panicked".into());
                    self.conclude(d.id, Some(err), shared);
                }
            }
        }
        let mut st = plock(shared);
        st.decode_rounds += 1;
        for ms in step_ms {
            st.decode_step_latency.record_ms(ms);
            st.decode_steps += 1;
        }
    }

    /// Terminal state for a streaming session: release its KV pages and
    /// prefix pin, account the outcome, answer the client. `error = None`
    /// is success; a cancelled/expired/faulted session still reports its
    /// partial `generated`/`nll` payload.
    fn conclude(&mut self, id: u64, error: Option<ServerError>, shared: &Mutex<SharedStats>) {
        let Some(s) = self.sessions.remove(&id).or_else(|| self.parked.remove(&id)) else {
            return;
        };
        self.kv.evict(id);
        if let (Some(pin), Some(cache)) = (s.cache_pin, self.cache.as_mut()) {
            cache.release(pin);
        }
        self.cancels.remove(id);
        self.faulted_admits.remove(&id);
        let lat = s.arrived.elapsed();
        let context = s.sess.pos();
        let retained = s.sess.min_retained().unwrap_or(context);
        let fallback = s.sess.states().iter().any(|st| st.fallback_used());
        // Realized key budget at the terminal step, per layer·head state —
        // the observable half of a `mass=` budget (fixed budgets realize
        // their top_k, so this is a constant for them).
        let realized: Vec<usize> = s
            .sess
            .states()
            .iter()
            .filter_map(|st| st.selection().map(|sel| sel.len()))
            .collect();
        let (rmean, rp50, rp99) = realized_summary(&realized, context);
        {
            let mut st = plock(shared);
            // Streamed-token accounting covers partial output too: a
            // cancelled/expired/faulted session already pushed its tokens
            // to the stream.
            st.streamed_tokens += s.generated.len();
            st.tenant_mut(&s.tenant).streamed_tokens += s.generated.len();
            match &error {
                None => {
                    st.latency.record(lat);
                    st.realized_keys.record_ms(rmean);
                    st.completed += 1;
                    st.scored_tokens += s.nll.len() + s.generated.len();
                    st.tenant_mut(&s.tenant).requests += 1;
                    if s.rung > 0 {
                        st.degraded += 1;
                    }
                }
                Some(err) => st.record_failure(&s.tenant, err),
            }
        }
        let decode_steps = s.generated.len();
        let resp = Response {
            id,
            nll: s.nll,
            generated: s.generated,
            latency_ms: lat.as_secs_f64() * 1e3,
            kernel: self.kernel.to_string(),
            retained_keys: retained,
            realized_keys_mean: rmean,
            realized_keys_p50: rp50,
            realized_keys_p99: rp99,
            fallback_used: fallback,
            decode_steps,
            decode_ms: s.decode_ms,
            degraded: s.rung > 0,
            spec: self.rungs[s.rung].spec_str.clone(),
            error,
        };
        // Session terminals route through the hub (exactly once, stored for
        // late resumes); a detached-for-persist or non-session id falls back
        // to the direct response channel.
        if !self.hub.finish(id, &resp) {
            if let Some(tx) = s.respond {
                let _ = tx.send(resp);
            }
        }
    }

    /// Wake `id` after a resume re-attached its session: a parked session
    /// rejoins decode scheduling; an id still live in any phase (racing the
    /// park at a safe point) needs no wake. Returns whether the id is live
    /// in this engine at all — `false` means the caller must re-admit.
    fn wake_or_live(&mut self, id: u64) -> bool {
        if let Some(s) = self.parked.remove(&id) {
            let lane = s.lane;
            self.sessions.insert(id, s);
            self.scheduler.submit_decode_for(lane, id);
            return true;
        }
        self.sessions.contains_key(&id)
            || self.in_flight.contains_key(&id)
            || self.pending.contains_key(&id)
            || self.checked_out.contains_key(&id)
    }

    /// Lifecycle sweep: conclude sessions whose linger window elapsed while
    /// parked (Cancelled — the PR 6 reclaim path, pages/pins released) and
    /// GC expired detached entries. Ids the hub no longer tracks but the
    /// engine still runs (a park/finish race) get their cancel token
    /// tripped so the next safe point concludes them.
    fn expire_sessions(&mut self, shared: &Mutex<SharedStats>) {
        for id in self.hub.take_expired() {
            if self.parked.contains_key(&id) || self.sessions.contains_key(&id) {
                self.conclude(id, Some(ServerError::Cancelled), shared);
            } else {
                self.cancels.cancel(id);
            }
        }
    }

    /// Shutdown-drain step: detach every parked session for persistence
    /// (the hub keeps it as a resumable record for `save_cache`), then run
    /// the normal teardown so its pages and pins release with balanced
    /// accounting. The terminal is Cancelled — the client is gone; a future
    /// incarnation serves the resume from the persisted record instead.
    fn drain_parked(&mut self, shared: &Mutex<SharedStats>) {
        let ids: Vec<u64> = self.parked.keys().copied().collect();
        for id in ids {
            self.hub.detach_for_persist(id);
            self.conclude(id, Some(ServerError::Cancelled), shared);
        }
    }
}

/// The shared handles a live stats snapshot reads from: the counter block,
/// the (optional) engine for KV/prefix accounting, and the static facts
/// (worker count, kernel, start instant). One copy lives in the server
/// handle, one in the run loop — `snapshot_stats` works from either side
/// while the server is serving.
#[derive(Clone)]
struct StatsSources {
    shared: Arc<Mutex<SharedStats>>,
    engine: Option<Arc<Mutex<DecodeEngine>>>,
    hub: Arc<SessionHub>,
    workers: usize,
    kernel: String,
    started: Instant,
}

/// The scoring server: coordinator thread + executor worker pool.
pub struct ScoringServer {
    jobs_tx: Sender<Job>,
    /// Request-id → cancel-token map shared with the serving threads.
    cancels: Arc<CancelRegistry>,
    /// Live-stats handles shared with the run loop ([`ScoringServer::stats`]).
    stats_src: StatsSources,
    /// Resumable-session registry shared with the decode engine.
    hub: Arc<SessionHub>,
    handle: Option<std::thread::JoinHandle<ServerStats>>,
}

impl ScoringServer {
    /// Start the server. `variant` picks the artifact family
    /// ("exact" | "prescored_k64" | ...).
    ///
    /// PJRT handles are not `Send`, so each worker constructs its registry
    /// *inside* its own thread; artifact availability is pre-flighted here
    /// so misconfiguration fails fast on the caller. When the artifacts
    /// directory holds a trained `weights.bin`, the pure-Rust decode engine
    /// is enabled for generation requests (and as the scoring fallback when
    /// no artifact is loadable).
    pub fn start(cfg: ServingConfig) -> Result<ScoringServer> {
        let model = load_substrate_model(&cfg);
        Self::start_inner(cfg, model)
    }

    /// Start with an explicit substrate model (tests / embedded use): the
    /// decode engine runs on `model`, and artifacts are optional — with no
    /// artifacts, scoring requests are served by the substrate too.
    pub fn start_with_model(cfg: ServingConfig, model: Transformer) -> Result<ScoringServer> {
        Self::start_inner(cfg, Some(model))
    }

    fn start_inner(cfg: ServingConfig, model: Option<Transformer>) -> Result<ScoringServer> {
        let (jobs_tx, jobs_rx): (Sender<Job>, Receiver<Job>) = channel();
        // Single construction path: [attention] spec (or the legacy-key
        // derivation) → backend. Misconfiguration fails fast here; the
        // backend is the source of per-request AttnStats, so the spec —
        // explicit or derived — must describe the kernel the artifact
        // variant actually executes (see validate_spec_for_variant), or the
        // reported stats would describe a kernel that never ran.
        let spec = cfg.attention_spec()?;
        let dir = Path::new(&cfg.artifacts_dir).to_path_buf();
        let buckets = ArtifactRegistry::new(&dir, cfg.max_seq).available_batches(&cfg.variant);
        // Substrate-only serving (model, no artifacts) runs any spec; once
        // artifacts execute requests the spec must describe them.
        if !(buckets.is_empty() && model.is_some()) {
            validate_spec_for_variant(&spec, &cfg.variant)?;
        }
        if buckets.is_empty() && model.is_none() {
            anyhow::bail!(
                "no artifacts for variant '{}' in {} — run `make artifacts`",
                cfg.variant,
                dir.display()
            );
        }
        let backend: Box<dyn AttentionBackend> = spec.build();
        // Arm the deterministic fault hooks if the environment asks for
        // them (PALLAS_FAULT_PLAN / PALLAS_FAULT_SEED); no-op otherwise.
        crate::fault::install_from_env();
        let cancels = Arc::new(CancelRegistry::new());
        let loop_cancels = Arc::clone(&cancels);
        let hub =
            Arc::new(SessionHub::new(cfg.session_linger_ms, cfg.session_replay_tokens));
        let engine = model.map(|m| {
            Arc::new(Mutex::new(DecodeEngine::new(
                m,
                &cfg,
                &spec,
                Arc::clone(&cancels),
                Arc::clone(&hub),
            )))
        });
        let stats_src = StatsSources {
            shared: Arc::new(Mutex::new(SharedStats::default())),
            engine,
            hub: Arc::clone(&hub),
            workers: worker_count(&cfg),
            kernel: backend.kernel_name().to_string(),
            started: Instant::now(),
        };
        let loop_src = stats_src.clone();
        let handle = std::thread::spawn(move || {
            run_loop(cfg, buckets, jobs_rx, backend, spec, loop_src, loop_cancels)
        });
        Ok(ScoringServer { jobs_tx, cancels, stats_src, hub, handle: Some(handle) })
    }

    /// Submit a request; returns the channel the response arrives on. A
    /// submit that races shutdown gets a typed `Internal` failure on that
    /// channel instead of a panic.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.cancels.register(request.id);
        if let Err(e) =
            self.jobs_tx.send(Job { request, respond: tx, stream: None, session: false })
        {
            let Job { request, respond, .. } = e.0;
            self.cancels.remove(request.id);
            let _ = respond.send(Response::failure(
                request.id,
                ms_since(request.arrived),
                String::new(),
                ServerError::Internal("server is shut down".into()),
            ));
        }
        rx
    }

    /// Submit a generation request with a per-step token stream: a
    /// [`StreamEvent`] arrives on the first channel as each decode step
    /// lands (the first one before generation completes), and the terminal
    /// [`Response`] — success or typed failure, exactly once — arrives on
    /// the second. The event channel disconnects when the session reaches
    /// its terminal state, so `recv() == Err` on the event channel means
    /// the terminal response is available or imminent.
    pub fn submit_streaming(
        &self,
        request: Request,
    ) -> (Receiver<StreamEvent>, Receiver<Response>) {
        let (ev_tx, ev_rx) = channel();
        let (tx, rx) = channel();
        self.cancels.register(request.id);
        if let Err(e) = self
            .jobs_tx
            .send(Job { request, respond: tx, stream: Some(ev_tx), session: false })
        {
            let Job { request, respond, .. } = e.0;
            self.cancels.remove(request.id);
            let _ = respond.send(Response::failure(
                request.id,
                ms_since(request.arrived),
                String::new(),
                ServerError::Internal("server is shut down".into()),
            ));
        }
        (ev_rx, rx)
    }

    /// Open a **resumable** streaming session: like `submit_streaming`, but
    /// tokens and the terminal route through the [`SessionHub`] — sequence-
    /// numbered, replay-buffered, and parked (not cancelled) when the
    /// client vanishes. Returns the server-issued session id a client
    /// echoes back in `Last-Event-ID`, plus the event/terminal receivers
    /// for this attachment.
    pub fn open_session(
        &self,
        request: Request,
    ) -> (String, Receiver<StreamEvent>, Receiver<Response>) {
        let (ev_tx, ev_rx) = channel();
        let (term_tx, term_rx) = channel();
        let id = request.id;
        let arrived = request.arrived;
        let sid = self.hub.open(
            id,
            &request.tenant,
            request.tokens.clone(),
            request.generate,
            ev_tx,
            term_tx,
        );
        self.cancels.register(id);
        // The hub owns the only live terminal channel; the Job's respond
        // sender deliberately goes nowhere (see `conclude`'s finish-first
        // delivery) so a session can never receive two terminals.
        let (dangle, _nobody) = channel();
        if self
            .jobs_tx
            .send(Job { request, respond: dangle, stream: None, session: true })
            .is_err()
        {
            self.cancels.remove(id);
            let resp = Response::failure(
                id,
                ms_since(arrived),
                String::new(),
                ServerError::Internal("server is shut down".into()),
            );
            self.hub.finish(id, &resp);
        }
        (sid, ev_rx, term_rx)
    }

    /// The client of `sid` vanished: park the session. Decode pauses at the
    /// next safe point with KV pages and prefix pins held; the entry stays
    /// resumable for `session_linger_ms` before the cancel path reclaims
    /// it. Returns `false` for unknown or already-finished sessions.
    pub fn park_session(&self, sid: &str) -> bool {
        self.hub.park(sid).is_some()
    }

    /// Re-attach a client to `sid` at cursor `after` (the sequence number
    /// from `Last-Event-ID`; 0 = from the start). On success the ticket
    /// carries the buffered `(seq, token)` suffix to replay and the live
    /// event/terminal receivers. A parked session wakes in place; a
    /// session restored from a persisted store re-admits its context under
    /// `new_id` — warm through the prefix cache, fast-forwarded by the
    /// hub's high-water suppression, bitwise identical under greedy decode.
    pub fn resume_session(
        &self,
        sid: &str,
        after: usize,
        new_id: u64,
    ) -> Result<SessionTicket, ResumeError> {
        let (ev_tx, ev_rx) = channel();
        let (term_tx, term_rx) = channel();
        let out = self.hub.attach_for_resume(sid, after, ev_tx, term_tx)?;
        let ticket = |done: Option<Response>| SessionTicket {
            session_id: sid.to_string(),
            replay: out.replay.clone(),
            events: ev_rx,
            terminal: term_rx,
            done,
        };
        if out.done.is_some() {
            // Already finished: replay + stored terminal, engine untouched.
            return Ok(ticket(out.done.clone()));
        }
        let live = out.engine_bound
            && self
                .stats_src
                .engine
                .as_deref()
                .map_or(false, |e| plock(e).wake_or_live(out.request_id));
        if !live {
            // Restored from a persisted store (or the engine already tore
            // the old id down): re-admit the full context under a fresh id.
            // The prefill is warm through the restored prefix cache and the
            // regenerated prefix is suppressed below the high-water mark.
            self.hub.rekey(sid, new_id);
            self.cancels.register(new_id);
            let mut request = Request::scoring(new_id, out.context.clone())
                .with_tenant(&out.tenant);
            request.generate = out.target;
            let arrived = request.arrived;
            let (dangle, _nobody) = channel();
            if self
                .jobs_tx
                .send(Job { request, respond: dangle, stream: None, session: true })
                .is_err()
            {
                self.cancels.remove(new_id);
                let resp = Response::failure(
                    new_id,
                    ms_since(arrived),
                    String::new(),
                    ServerError::Internal("server is shut down".into()),
                );
                self.hub.finish(new_id, &resp);
            }
        }
        Ok(ticket(None))
    }

    /// Live statistics snapshot (the gateway's `/v1/stats`). Counters are
    /// monotone; a snapshot taken mid-flight reflects the work that has
    /// reached a terminal state so far. The final `shutdown()` stats are
    /// the same snapshot taken after the queue drains.
    pub fn stats(&self) -> ServerStats {
        snapshot_stats(&self.stats_src)
    }

    /// Cancel an in-flight request from any thread. The request reaches a
    /// terminal `ServerError::Cancelled` response at the next safe point
    /// (admission, the prefill→decode boundary, or between decode rounds)
    /// with its KV pages and prefix pins released. Returns `false` when the
    /// id is unknown or already finished — a post-completion no-op.
    pub fn cancel(&self, id: u64) -> bool {
        self.cancels.cancel(id)
    }

    /// Stop the server (drains the queue) and return final statistics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.jobs_tx);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_else(|_| {
                eprintln!("server coordinator thread panicked; reporting empty stats");
                ServerStats::default()
            }),
            None => ServerStats::default(),
        }
    }
}

/// Load the pure-Rust substrate model from `weights.bin` if present.
fn load_substrate_model(cfg: &ServingConfig) -> Option<Transformer> {
    let path = Path::new(&cfg.artifacts_dir).join("weights.bin");
    if !path.exists() {
        return None;
    }
    match WeightStore::load(&path) {
        Ok(ws) => Some(Transformer::from_weights(&ws, TransformerConfig::default())),
        Err(e) => {
            eprintln!("failed to load substrate weights {}: {e:#}", path.display());
            None
        }
    }
}

/// Gate the attention spec (explicit `[attention] spec` or the legacy-key
/// derivation) against the artifact variant that actually executes
/// requests. Serving artifacts exist for two kernel families only: `exact`
/// artifacts run exact attention (an `exact` or `flash` spec), and
/// `prescored_k<K>` artifacts bake in Algorithm 2 with a fixed key budget K
/// (a `prescored:` spec whose `top_k` matches K). Other spec kernels
/// (`hyper:`, `restricted:`) run on the pure-Rust substrate (`ppl` CLI,
/// benches, the substrate-only server mode) but have no serving artifact.
/// The δ-threshold and method are not encoded in the variant name and
/// cannot be cross-checked.
fn validate_spec_for_variant(spec: &AttentionSpec, variant: &str) -> Result<()> {
    use crate::attention::PreScoreMode;
    // Streaming pre-scoring is a substrate-only kernel: the prescored_k<K>
    // artifacts bake in the full-recluster Algorithm 2, so a stream spec
    // would misdescribe what executes (substrate-only servers skip this
    // gate entirely and serve stream specs end to end).
    if let AttentionSpec::PreScored(cfg) = spec {
        if cfg.mode == PreScoreMode::Stream && variant.starts_with("prescored") {
            anyhow::bail!(
                "attention spec '{spec}' uses mode=stream, which has no serving \
                 artifact — prescored_k<K> artifacts bake in the full re-cluster; \
                 stream specs run on the pure-Rust substrate (weights.bin) only"
            );
        }
    }
    if let Some(k) =
        variant.strip_prefix("prescored_k").and_then(|k| k.parse::<usize>().ok())
    {
        match spec {
            AttentionSpec::PreScored(cfg)
                if cfg.prescore.budget == crate::prescore::KeyBudget::Fixed(k) =>
            {
                return Ok(())
            }
            // A mass budget (or any other fixed k) mismatches a baked-in
            // prescored_k<K> artifact: its realized k is data-dependent,
            // never the artifact's constant.
            AttentionSpec::PreScored(cfg) => anyhow::bail!(
                "attention spec retains {} but artifact variant '{variant}' bakes \
                 in k={k} — per-request stats would misreport the retained budget \
                 (set [attention] spec / [prescore] top_k to match the variant)",
                cfg.prescore.budget
            ),
            _ => {}
        }
    } else if variant.starts_with("prescored") {
        // Prescored family without a parseable budget: family check only.
        if matches!(spec, AttentionSpec::PreScored(_)) {
            return Ok(());
        }
    } else if matches!(spec, AttentionSpec::Exact | AttentionSpec::Flash { .. }) {
        return Ok(());
    }
    anyhow::bail!(
        "attention spec '{spec}' is inconsistent with artifact variant '{variant}': \
         exact artifacts serve exact/flash specs, prescored_k<K> artifacts serve \
         prescored specs with the matching top_k; hyper/restricted specs run on the \
         pure-Rust substrate (ppl CLI, benches) and have no serving artifact"
    )
}

/// Resolve the executor pool width from config / the global parallel pool.
fn worker_count(cfg: &ServingConfig) -> usize {
    if cfg.executor_workers > 0 {
        return cfg.executor_workers;
    }
    parallel::num_threads().clamp(1, 8)
}

fn run_loop(
    cfg: ServingConfig,
    buckets: Vec<usize>,
    jobs_rx: Receiver<Job>,
    backend: Box<dyn AttentionBackend>,
    spec: AttentionSpec,
    src: StatsSources,
    cancels: Arc<CancelRegistry>,
) -> ServerStats {
    let deadline = Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3);
    // Substrate-only mode has no compiled lane buckets; batch up to the
    // configured batch size on the model path instead.
    let lane_buckets =
        if buckets.is_empty() { vec![cfg.batch_size.max(1)] } else { buckets.clone() };
    let mut batcher = DynamicBatcher::new(BatcherConfig {
        buckets: lane_buckets,
        max_batch_tokens: cfg.max_batch_tokens,
        max_seq: cfg.max_seq,
        deadline,
    });
    // Canonical spec string for Response::spec on the scoring path (the
    // decode engine reports per-rung strings instead).
    let spec_str = spec.to_string();
    // The engine and counter block are shared with the server handle (live
    // `stats()` snapshots); the run loop borrows through the same Arcs.
    let engine: Option<&Mutex<DecodeEngine>> = src.engine.as_deref();
    let shared: &Mutex<SharedStats> = &src.shared;
    let hub: &SessionHub = &src.hub;
    let mut responders: HashMap<u64, Sender<Response>> = Default::default();
    let workers = src.workers;
    let queue = WorkQueue::new();
    // The coordinator blocks on `recv_timeout` instead of sleep-polling:
    // with work queued it sleeps exactly to the oldest request's flush
    // deadline; idle it parks until the next submission (bounded so the
    // shutdown drain still makes progress). Decode rounds are re-pumped by
    // the workers themselves, so decode cadence never waits on this loop.
    let idle_wait = Duration::from_millis(50);
    let min_wait = Duration::from_micros(50);

    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = &queue;
            let shared = shared;
            let cfg = &cfg;
            let buckets = &buckets;
            let backend = backend.as_ref();
            let engine = engine;
            let cancels = &cancels;
            let spec_str = &spec_str;
            s.spawn(move || {
                // Per-worker registry (PJRT handles are not Send). Every
                // bucket is pre-compiled before the worker takes traffic.
                let mut registry =
                    ArtifactRegistry::new(Path::new(&cfg.artifacts_dir), cfg.max_seq);
                for &b in buckets {
                    if let Err(e) = registry.get_or_load(&cfg.variant, b) {
                        eprintln!("failed to compile artifact bucket {b}: {e:#}");
                    }
                }
                let drained = || engine.map_or(true, |e| !plock(e).active());
                while let Some(work) = queue.pop(&drained) {
                    match work {
                        Work::Score { batch, responders } => {
                            // Panic isolation: keep enough (id, arrived,
                            // responder clone) to fail exactly this batch's
                            // requests if the execution panics; the worker
                            // rejoins the drain loop either way.
                            let fallback: Vec<(u64, Instant, String, Option<Sender<Response>>)> =
                                batch
                                    .requests
                                    .iter()
                                    .zip(&responders)
                                    .map(|(r, tx)| {
                                        (r.id, r.arrived, r.tenant.clone(), tx.clone())
                                    })
                                    .collect();
                            let res = catch_unwind(AssertUnwindSafe(|| {
                                execute_batch(
                                    cfg,
                                    &mut registry,
                                    batch,
                                    responders,
                                    shared,
                                    backend,
                                    engine,
                                    cancels,
                                    spec_str,
                                )
                            }));
                            if res.is_err() {
                                {
                                    let mut st = plock(shared);
                                    st.worker_panics += 1;
                                    for (_, _, tenant, _) in &fallback {
                                        st.internal_errors += 1;
                                        st.tenant_mut(tenant).requests += 1;
                                    }
                                }
                                for (id, arrived, _tenant, tx) in fallback {
                                    cancels.remove(id);
                                    if let Some(tx) = tx {
                                        let _ = tx.send(Response::failure(
                                            id,
                                            ms_since(arrived),
                                            spec_str.clone(),
                                            ServerError::Internal(
                                                "scoring worker panicked".into(),
                                            ),
                                        ));
                                    }
                                }
                            }
                        }
                        Work::Gen(item) => {
                            let Some(eng) = engine else { continue };
                            let ids: Vec<u64> = match &item {
                                WorkItem::Prefill(ids) | WorkItem::Decode(ids) => ids.clone(),
                            };
                            // Decode-step panics are already scoped inside
                            // run_decode; this boundary catches the rest of
                            // the item (notably the lock-free prefill
                            // forward) and fails only its requests.
                            let res =
                                catch_unwind(AssertUnwindSafe(|| execute_gen(item, eng, shared)));
                            if res.is_err() {
                                plock(shared).worker_panics += 1;
                                let mut g = plock(eng);
                                for id in ids {
                                    g.fail_request(id, shared);
                                }
                            }
                            // Re-pump: keep decode rounds flowing without
                            // waiting for the coordinator's next wake.
                            let follow = plock(eng).next_round(1);
                            for it in follow {
                                queue.push(Work::Gen(it));
                            }
                        }
                    }
                }
            });
        }

        let engine_active = || engine.map_or(false, |e| plock(e).active());
        let mut open = true;
        while open || batcher.queue_len() > 0 || engine_active() {
            // Admit jobs: block until the next flush deadline (or a new
            // submission, whichever first), then drain whatever else is
            // already queued.
            let wait = batcher
                .time_to_deadline(Instant::now())
                .map(|d| d.clamp(min_wait, idle_wait))
                .unwrap_or(idle_wait);
            let route = |job: Job,
                             responders: &mut HashMap<u64, Sender<Response>>,
                             batcher: &mut DynamicBatcher| {
                if job.request.generate > 0 {
                    match engine {
                        Some(e) => plock(e).admit(job),
                        None => {
                            // Typed failure rather than silently serving a
                            // generation request as scoring-only (or a
                            // dropped channel the client can't classify).
                            cancels.remove(job.request.id);
                            let resp = Response::failure(
                                job.request.id,
                                ms_since(job.request.arrived),
                                spec_str.clone(),
                                ServerError::Unsupported(
                                    "generation requires a substrate model (weights.bin)"
                                        .into(),
                                ),
                            );
                            if !hub.finish(job.request.id, &resp) {
                                let _ = job.respond.send(resp);
                            }
                        }
                    }
                    return;
                }
                responders.insert(job.request.id, job.respond);
                batcher.push(job.request);
            };
            if open {
                match jobs_rx.recv_timeout(wait) {
                    Ok(job) => {
                        route(job, &mut responders, &mut batcher);
                        loop {
                            match jobs_rx.try_recv() {
                                Ok(job) => route(job, &mut responders, &mut batcher),
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => open = false,
                }
            } else {
                // Shutdown drain: no new jobs can arrive; parked sessions
                // detach into persistable records (their pages release with
                // balanced accounting) and the loop paces while in-flight
                // decode sequences finish.
                if let Some(e) = engine {
                    plock(e).drain_parked(shared);
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            // Ship every batch the policy allows right now.
            while let Some(batch) = batcher.poll(Instant::now()) {
                ship(batch, &mut responders, &queue, &cancels, &shared, &spec_str);
            }
            if !open {
                for batch in batcher.drain_all() {
                    ship(batch, &mut responders, &queue, &cancels, &shared, &spec_str);
                }
            }
            // Session-lifecycle sweep (parked entries past their linger
            // window reclaim through the cancel path), then seed engine
            // rounds (workers keep them flowing afterwards).
            if let Some(e) = engine {
                let mut g = plock(e);
                g.expire_sessions(shared);
                let round = g.next_round(workers);
                drop(g);
                for it in round {
                    queue.push(Work::Gen(it));
                }
            }
        }
        // Close the work queue: workers finish in-flight work (including
        // decode rounds still re-pumping) and exit; the scope joins them
        // before we assemble the final stats.
        queue.close();
    });

    // Final prefix-cache persistence + the terminal stats snapshot. The
    // engine/counter handles stay shared with `ScoringServer::stats`, so
    // this is the same (lock-based) snapshot a live reader takes — just
    // after the scope has joined every worker, when the engine is
    // quiescent.
    if let Some(e) = engine {
        plock(e).save_cache();
    }
    snapshot_stats(&src)
}

/// Assemble a [`ServerStats`] from the live handles. Safe to call from any
/// thread while the server runs: the engine lock is taken and released for
/// the KV/prefix numbers *before* the counter lock (engine → shared is the
/// process-wide lock order, and the two are never held together here).
fn snapshot_stats(src: &StatsSources) -> ServerStats {
    let (prefix, kv_acquired, kv_released, kv_free, kv_cap) = match src.engine.as_deref() {
        Some(e) => {
            let eng = plock(e);
            (
                eng.cache_stats(),
                eng.kv.pages_acquired(),
                eng.kv.pages_released(),
                eng.kv.free_blocks(),
                eng.kv.capacity(),
            )
        }
        None => (CacheStats::default(), 0, 0, 0, 0),
    };
    // Hub counters after the engine lock is released (the hub has its own
    // lock; never held together with the engine's here).
    let sessions: SessionCounters = src.hub.counters();
    let elapsed = src.started.elapsed().as_secs_f64().max(1e-9);
    let stats = plock(&src.shared);
    let mut tenants: Vec<TenantStats> = stats
        .tenants
        .iter()
        .map(|(tenant, c)| TenantStats {
            tenant: tenant.clone(),
            requests: c.requests,
            streamed_tokens: c.streamed_tokens,
            sheds: c.sheds,
            cancels: c.cancels,
        })
        .collect();
    tenants.sort_by(|a, b| a.tenant.cmp(&b.tenant));
    ServerStats {
        completed: stats.completed,
        batches: stats.batches,
        total_lanes: stats.total_lanes.max(1),
        occupied_lanes: stats.occupied_lanes,
        latency_p50_ms: stats.latency.percentile(50.0),
        latency_p99_ms: stats.latency.percentile(99.0),
        throughput_rps: stats.completed as f64 / elapsed,
        tokens_per_s: stats.scored_tokens as f64 / elapsed,
        workers: src.workers,
        kernel: src.kernel.clone(),
        prefills: stats.prefills,
        decode_rounds: stats.decode_rounds,
        decode_steps: stats.decode_steps,
        decode_step_p50_ms: stats.decode_step_latency.percentile(50.0),
        decode_step_p99_ms: stats.decode_step_latency.percentile(99.0),
        prefix_hits: prefix.hits,
        prefix_misses: prefix.misses,
        prefix_hit_tokens: prefix.hit_tokens,
        prefix_insertions: prefix.insertions,
        prefix_evictions: prefix.evictions,
        prefix_nodes: prefix.nodes,
        prefix_cached_tokens: prefix.cached_tokens,
        tier_spills: prefix.tier_spills,
        tier_readmits: prefix.tier_readmits,
        tier_bytes: prefix.tier_bytes,
        cancelled: stats.cancelled,
        expired: stats.expired,
        degraded: stats.degraded,
        shed_rejects: stats.shed_rejects,
        internal_errors: stats.internal_errors,
        worker_panics: stats.worker_panics,
        kv_pages_acquired: kv_acquired,
        kv_pages_released: kv_released,
        kv_pages_reclaimed: stats.kv_pages_reclaimed,
        prefix_pins_acquired: prefix.pins_acquired,
        prefix_pins_released: prefix.pins_released,
        shed_level: stats.shed_level,
        streamed_tokens: stats.streamed_tokens,
        sessions_live: sessions.live,
        sessions_parked: sessions.parked,
        sessions_resumed: sessions.resumed,
        sessions_expired: sessions.expired,
        sessions_persisted: sessions.persisted,
        sessions_recovered: sessions.recovered,
        kv_free_pages: kv_free,
        kv_capacity_pages: kv_cap,
        realized_keys_mean: stats.realized_keys.mean(),
        realized_keys_p50: stats.realized_keys.percentile(50.0),
        realized_keys_p99: stats.realized_keys.percentile(99.0),
        rung_served: stats.rung_served.clone(),
        tenants,
    }
}

/// Summarize a request's per-state realized key counts as (mean, p50, p99).
/// Kernels without per-state selections report the full context uniformly —
/// the same convention `Response::retained_keys` uses.
fn realized_summary(counts: &[usize], context: usize) -> (f64, usize, usize) {
    if counts.is_empty() {
        return (context as f64, context, context);
    }
    let mut sorted = counts.to_vec();
    sorted.sort_unstable();
    let mean = sorted.iter().sum::<usize>() as f64 / sorted.len() as f64;
    let at = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
    (mean, at(0.50), at(0.99))
}

/// Pair a formed batch with its responders and enqueue it for the pool.
/// Ship time is the scoring path's safe point: cancelled/expired requests
/// are answered with a typed failure here (their lane still executes — the
/// batch shape is already formed — but the result is discarded).
fn ship(
    batch: Batch,
    responders: &mut HashMap<u64, Sender<Response>>,
    queue: &WorkQueue,
    cancels: &CancelRegistry,
    shared: &Mutex<SharedStats>,
    spec_str: &str,
) {
    let txs: Vec<Option<Sender<Response>>> = batch
        .requests
        .iter()
        .map(|req| {
            let tx = responders.remove(&req.id);
            let verdict = if cancels.get(req.id).map_or(false, |t| t.is_cancelled()) {
                Some(ServerError::Cancelled)
            } else if req.expired() {
                Some(ServerError::DeadlineExceeded)
            } else {
                None
            };
            match verdict {
                Some(err) => {
                    cancels.remove(req.id);
                    plock(shared).record_failure(&req.tenant, &err);
                    if let Some(tx) = tx {
                        let _ = tx.send(Response::failure(
                            req.id,
                            ms_since(req.arrived),
                            spec_str.to_string(),
                            err,
                        ));
                    }
                    None
                }
                None => tx,
            }
        })
        .collect();
    queue.push(Work::Score { batch, responders: txs });
}

/// Phase 2 of a prefill — the forward itself, run WITHOUT the engine lock
/// (model/policy are `Arc` handles) so decode rounds keep flowing while a
/// long prefill computes.
///
/// Warm path: rebuild the session from the cache hit, stitch the boundary
/// NLL entry from the cached logits row, and push only the un-cached suffix
/// through `resume_decode` — O(suffix) forward work, bitwise-identical
/// logits/NLL to the cold path. Cold path: full `begin_decode`.
fn prefill_compute(prep: PrefillPrep) -> PrefillOutcome {
    let PrefillPrep {
        id,
        tokens,
        respond,
        arrived,
        generate,
        hit,
        model,
        policy,
        want_snapshot,
        kv_dtype,
    } = prep;
    // Session KV rows live on the configured dtype grid (snapped once at
    // capture); snapshots pack them losslessly into [`KvStore`] pages, so
    // warm hits — RAM or disk-tier — reproduce the capture bitwise.
    let pack = |kv: Vec<(Matrix, Matrix)>| -> Vec<(KvStore, KvStore)> {
        kv.into_iter()
            .map(|(k, v)| (KvStore::from_matrix(k, kv_dtype), KvStore::from_matrix(v, kv_dtype)))
            .collect()
    };
    let result = (|| -> Result<PrefillDone> {
        match hit {
            Some(h) => {
                let warm = h.len;
                let cache_pin = Some(h.node);
                // O(prefix) materialization (KV rows AND the owned decode
                // states) happens HERE, outside the engine lock — the
                // lock-held lookup only cloned Arc handles.
                let kv = h.assemble_kv();
                let states = h.states.as_ref().clone();
                let mut sess = DecodeSession::from_cache_dtype(kv, states, warm, kv_dtype);
                let mut nll = h.nll;
                let mut last = h.last_logits;
                if tokens.len() > warm {
                    // Boundary entry: cached logits row at warm−1 scores the
                    // first un-cached token.
                    nll.push(nll_entry(&last, tokens[warm]));
                    let suffix_logits = model.resume_decode(&mut sess, &tokens[warm..], &policy);
                    let m = suffix_logits.rows;
                    for r in 0..m.saturating_sub(1) {
                        nll.push(nll_entry(suffix_logits.row(r), tokens[warm + r + 1]));
                    }
                    last = suffix_logits.row(m - 1).to_vec();
                }
                let next_token = argmax_row(&last);
                let snapshot = want_snapshot.then(|| {
                    // The cached rows already live in the tree: snapshot
                    // only the suffix the warm path computed (O(suffix)
                    // clone, matching the warm path's cost contract).
                    (
                        tokens.clone(),
                        PrefixSnapshot {
                            kv_from: warm,
                            kv: pack(sess.export_kv_suffix(warm)),
                            states: sess.clone_states(),
                            nll: nll.clone(),
                            last_logits: last.clone(),
                        },
                    )
                });
                Ok(PrefillDone { sess, nll, next_token, snapshot, cache_pin })
            }
            None => {
                let (logits, sess) = model.begin_decode_dtype(&tokens, &policy, kv_dtype)?;
                let nll = nll_from_logits(&logits, &tokens);
                let last = logits.row(logits.rows - 1);
                let next_token = argmax_row(last);
                let snapshot = want_snapshot.then(|| {
                    (
                        tokens.clone(),
                        PrefixSnapshot {
                            kv_from: 0,
                            kv: pack(sess.export_kv()),
                            states: sess.clone_states(),
                            nll: nll.clone(),
                            last_logits: last.to_vec(),
                        },
                    )
                });
                Ok(PrefillDone { sess, nll, next_token, snapshot, cache_pin: None })
            }
        }
    })();
    PrefillOutcome { id, respond, arrived, generate, result }
}

/// Execute one engine work item (prefill batch or decode round). Both
/// classes hold the engine lock only for their assembly and installation
/// phases — the forward / token steps run lock-free between them, so items
/// on different workers genuinely overlap.
fn execute_gen(item: WorkItem, engine: &Mutex<DecodeEngine>, shared: &Mutex<SharedStats>) {
    match item {
        WorkItem::Prefill(ids) => {
            for id in ids {
                let prep = plock(engine).prepare_prefill(id, shared);
                let Some(prep) = prep else { continue };
                let outcome = prefill_compute(prep);
                plock(engine).complete_prefill(outcome, shared);
            }
        }
        WorkItem::Decode(ids) => run_decode_round(&ids, engine, shared),
    }
}

/// One decode round through the three-phase worker-split engine: assemble
/// under the lock, step every scheduled session lock-free, apply the
/// results under the lock. Within the round the steps run sequentially on
/// this worker (matching the pre-split per-round semantics); across
/// workers, rounds overlap in the middle phase instead of serializing
/// behind the engine mutex.
fn run_decode_round(ids: &[u64], engine: &Mutex<DecodeEngine>, shared: &Mutex<SharedStats>) {
    let steps = plock(engine).prepare_decode(ids, shared);
    let done: Vec<DecodeStepDone> = steps.into_iter().map(decode_step_compute).collect();
    plock(engine).complete_decode(done, shared);
}

/// Phase 2 of a decode round: one token step, WITHOUT the engine lock.
/// Panics (injected or real) are caught per step; the session survives
/// with its partial tokens for the terminal response. Streaming clients
/// get their `StreamEvent` here, as the step lands.
fn decode_step_compute(step: DecodeStep) -> DecodeStepDone {
    let DecodeStep { id, sess, model, refresh } = step;
    crate::fault::maybe_slow(FaultPoint::SlowDecode, id);
    let mut slot = Some(sess);
    let result = catch_unwind(AssertUnwindSafe(|| {
        let Some(s) = slot.as_mut() else {
            return StepCompute { finished: true, step_ms: None, refresh_snap: None };
        };
        if crate::fault::fires(FaultPoint::DecodePanic, id) {
            panic!("injected decode-step panic for request {id}");
        }
        let t0 = Instant::now();
        let token = s.next_token;
        s.generated.push(token);
        // The rung's policy, not the engine's base one: degraded sessions
        // step under the spec they were truthfully admitted at.
        let row = model.decode_token(&mut s.sess, token, &s.policy);
        s.next_token = argmax_row(&row);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        s.decode_ms += ms;
        if let Some(hub) = &s.hub {
            // Sequence-numbered through the hub: buffered for replay,
            // suppressed below the high-water mark on a fast-forwarding
            // re-admit, forwarded live when a client is attached.
            hub.emit(id, s.generated.len(), token);
        } else if let Some(tx) = &s.stream {
            let _ = tx.send(StreamEvent { id, tokens: vec![token], total: s.generated.len() });
        }
        let finished =
            s.generated.len() >= s.target_new || s.sess.pos() >= model.cfg.max_seq;
        // Keep the cache's selection view fresh at the refresh cadence (the
        // states refresh themselves; complete_decode mirrors this snapshot
        // into the kv manager's selection sets).
        let refresh_snap = refresh.then(|| DecodeEngine::selections_snapshot(&s.sess));
        StepCompute { finished, step_ms: Some(ms), refresh_snap }
    }));
    match result {
        Ok(c) => DecodeStepDone { id, sess: slot, result: StepResult::Stepped(c) },
        Err(_) => DecodeStepDone { id, sess: slot, result: StepResult::Panicked },
    }
}

fn execute_batch(
    cfg: &ServingConfig,
    registry: &mut ArtifactRegistry,
    batch: Batch,
    responders: Vec<Option<Sender<Response>>>,
    shared: &Mutex<SharedStats>,
    backend: &dyn AttentionBackend,
    engine: Option<&Mutex<DecodeEngine>>,
    cancels: &CancelRegistry,
    spec_str: &str,
) {
    // Injected `WorkerPanic` fault: dies here, inside the worker's
    // catch_unwind, exercising the batch-wide typed-failure recovery.
    if batch.requests.iter().any(|r| crate::fault::fires(FaultPoint::WorkerPanic, r.id)) {
        panic!("injected scoring-worker panic");
    }
    let lanes = batch.lanes;
    let rt = match registry.get_or_load(&cfg.variant, lanes) {
        Ok(rt) => rt,
        Err(e) => {
            // No loadable artifact: score on the substrate model if the
            // decode engine carries one, otherwise fail the batch with a
            // typed error (never a silently dropped channel).
            match engine {
                Some(engine) => substrate_score(
                    batch, responders, shared, backend, engine, cancels, spec_str,
                ),
                None => {
                    let msg = format!("artifact load failed: {e:#}");
                    {
                        let mut st = plock(shared);
                        for req in &batch.requests {
                            st.internal_errors += 1;
                            st.tenant_mut(&req.tenant).requests += 1;
                        }
                    }
                    for (req, tx) in batch.requests.iter().zip(&responders) {
                        cancels.remove(req.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(Response::failure(
                                req.id,
                                ms_since(req.arrived),
                                spec_str.to_string(),
                                ServerError::Internal(msg.clone()),
                            ));
                        }
                    }
                }
            }
            return;
        }
    };
    // Pad each request to max_seq with BOS (0); pad empty lanes with zeros.
    let mut tokens: Vec<Vec<u32>> = Vec::with_capacity(lanes);
    let mut lens: Vec<usize> = Vec::with_capacity(lanes);
    for req in &batch.requests {
        let mut row = req.tokens.clone();
        row.truncate(cfg.max_seq);
        lens.push(row.len());
        row.resize(cfg.max_seq, 0);
        tokens.push(row);
    }
    while tokens.len() < lanes {
        tokens.push(vec![0; cfg.max_seq]);
        lens.push(0);
    }
    match rt.execute(&tokens) {
        Ok(out) => {
            let mut stats = plock(shared);
            stats.batches += 1;
            stats.prefills += 1;
            stats.total_lanes += lanes;
            stats.occupied_lanes += batch.requests.len();
            for (i, req) in batch.requests.iter().enumerate() {
                let valid = lens[i].saturating_sub(1);
                let nll = out.nll[i][..valid].to_vec();
                let lat = req.arrived.elapsed();
                cancels.remove(req.id);
                // Ship-time verdicts (cancelled/expired) already answered
                // and accounted this lane; don't count it as a completion.
                if let Some(tx) = &responders[i] {
                    stats.latency.record(lat);
                    stats.completed += 1;
                    stats.scored_tokens += valid;
                    stats.tenant_mut(&req.tenant).requests += 1;
                    // Real per-request stats from the backend this server is
                    // configured to serve (start() gates explicit specs
                    // against the artifact variant's family and key budget):
                    // the retention/fallback decision is a pure function of
                    // the context length and the backend config, so plan()
                    // reports what the kernel does for this request's
                    // context (previously hardcoded to cfg.prescore_top_k /
                    // false).
                    let attn = backend.plan(lens[i]);
                    stats.realized_keys.record_ms(attn.retained_keys as f64);
                    let _ = tx.send(Response {
                        id: req.id,
                        nll,
                        generated: Vec::new(),
                        latency_ms: lat.as_secs_f64() * 1e3,
                        kernel: attn.kernel.to_string(),
                        retained_keys: attn.retained_keys,
                        realized_keys_mean: attn.retained_keys as f64,
                        realized_keys_p50: attn.retained_keys,
                        realized_keys_p99: attn.retained_keys,
                        fallback_used: attn.fallback_used,
                        decode_steps: 0,
                        decode_ms: 0.0,
                        degraded: false,
                        spec: spec_str.to_string(),
                        error: None,
                    });
                }
            }
        }
        Err(e) => {
            let msg = format!("artifact execution failed: {e:#}");
            {
                let mut st = plock(shared);
                for req in &batch.requests {
                    st.internal_errors += 1;
                    st.tenant_mut(&req.tenant).requests += 1;
                }
            }
            for (req, tx) in batch.requests.iter().zip(&responders) {
                cancels.remove(req.id);
                if let Some(tx) = tx {
                    let _ = tx.send(Response::failure(
                        req.id,
                        ms_since(req.arrived),
                        spec_str.to_string(),
                        ServerError::Internal(msg.clone()),
                    ));
                }
            }
        }
    }
}

/// Scoring fallback on the pure-Rust substrate (no artifact required): full
/// forward + NLL per request under the engine's policy.
fn substrate_score(
    batch: Batch,
    responders: Vec<Option<Sender<Response>>>,
    shared: &Mutex<SharedStats>,
    backend: &dyn AttentionBackend,
    engine: &Mutex<DecodeEngine>,
    cancels: &CancelRegistry,
    spec_str: &str,
) {
    // Clone the immutable model/policy handles out of a brief lock and run
    // the (long) scoring forwards lock-free — substrate scoring can no
    // longer stall decode rounds behind the engine mutex.
    let (model, policy) = {
        let eng = plock(engine);
        (Arc::clone(&eng.model), Arc::clone(&eng.policy))
    };
    let max_seq = model.cfg.max_seq;
    let mut results: Vec<Vec<f32>> = Vec::with_capacity(batch.requests.len());
    for req in &batch.requests {
        let mut toks = req.tokens.clone();
        toks.truncate(max_seq);
        results.push(if toks.len() < 2 {
            Vec::new()
        } else {
            model.nll_policy(&toks, &policy)
        });
    }
    let mut stats = plock(shared);
    stats.batches += 1;
    stats.prefills += 1;
    stats.total_lanes += batch.lanes;
    stats.occupied_lanes += batch.requests.len();
    for (i, req) in batch.requests.iter().enumerate() {
        let lat = req.arrived.elapsed();
        cancels.remove(req.id);
        // A `None` responder was already answered at ship time (cancelled
        // or expired) — its lane ran because the batch shape was formed,
        // but it is not a completion.
        if let Some(tx) = &responders[i] {
            stats.latency.record(lat);
            stats.completed += 1;
            stats.scored_tokens += results[i].len();
            stats.tenant_mut(&req.tenant).requests += 1;
            let attn = backend.plan(req.tokens.len());
            stats.realized_keys.record_ms(attn.retained_keys as f64);
            let _ = tx.send(Response {
                id: req.id,
                nll: results[i].clone(),
                generated: Vec::new(),
                latency_ms: lat.as_secs_f64() * 1e3,
                kernel: attn.kernel.to_string(),
                retained_keys: attn.retained_keys,
                realized_keys_mean: attn.retained_keys as f64,
                realized_keys_p50: attn.retained_keys,
                realized_keys_p99: attn.retained_keys,
                fallback_used: attn.fallback_used,
                decode_steps: 0,
                decode_ms: 0.0,
                degraded: false,
                spec: spec_str.to_string(),
                error: None,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    // End-to-end server tests (substrate scoring + the decode engine on a
    // random model) live in rust/tests/integration_server.rs; unit coverage
    // for the pieces lives in coordinator::*.

    #[test]
    fn worker_count_respects_config_and_pool() {
        let pinned = ServingConfig { executor_workers: 3, ..Default::default() };
        assert_eq!(worker_count(&pinned), 3);
        let auto = ServingConfig { executor_workers: 0, ..Default::default() };
        let derived = crate::parallel::with_threads(5, || worker_count(&auto));
        assert_eq!(derived, 5);
        let capped = crate::parallel::with_threads(64, || worker_count(&auto));
        assert_eq!(capped, 8);
    }

    #[test]
    fn start_fails_fast_without_artifacts() {
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn start_fails_fast_on_bad_attention_spec() {
        // The spec pre-flight runs before the artifact scan, so a malformed
        // [attention] spec is rejected even without built artifacts.
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            attention_spec: "bogus:kernel".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("unknown attention kernel"));
    }

    #[test]
    fn start_rejects_spec_variant_mismatch() {
        // Response stats come from the configured backend; a spec that does
        // not describe the executing artifact would report stats for a
        // kernel that never ran.
        let base = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        // Wrong family: prescored spec on an exact artifact.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("inconsistent"), "{err:#}");
        // Right family, wrong baked-in budget.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // The gate also covers specs derived from the legacy [prescore]
        // keys — a [prescore] top_k that contradicts the variant is the
        // same misreporting bug.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            prescore_top_k: 128,
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // Unservable kernel: hyper has no artifact family at all.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "hyper:block=32".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("no serving artifact"), "{err:#}");
        // Streaming pre-scoring is substrate-only: the prescored artifacts
        // bake in the full re-cluster.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            attention_spec: "prescored:kmeans,top_k=64,mode=stream".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("mode=stream"), "{err:#}");
        // Consistent spec/variant pairs pass the gate (and fail later on
        // the missing artifacts instead).
        for (variant, spec) in
            [("prescored_k64", "prescored:kmeans,top_k=64"), ("exact", "flash")]
        {
            let cfg = ServingConfig {
                variant: variant.into(),
                attention_spec: spec.into(),
                ..base.clone()
            };
            let err = ScoringServer::start(cfg).err().expect("must fail");
            assert!(format!("{err:#}").contains("make artifacts"), "{variant}/{spec}: {err:#}");
        }
    }
}
