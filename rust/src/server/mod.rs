//! Thread-based serving loop (tokio substitute — see DESIGN.md).
//!
//! A `ScoringServer` owns the dynamic batcher and a PJRT model runtime per
//! compiled lane bucket; clients submit requests over an mpsc channel and
//! receive responses over per-request channels. The executor thread runs:
//! poll batcher → pad batch to the artifact shape → execute → respond.
//! Python is never on this path.

use crate::config::ServingConfig;
use crate::coordinator::{Batch, BatcherConfig, DynamicBatcher, Request, Response};
use crate::metrics::LatencyStats;
use crate::runtime::ArtifactRegistry;
use anyhow::Result;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// A submitted job: the request plus the channel to answer on.
pub struct Job {
    pub request: Request,
    pub respond: Sender<Response>,
}

/// Server statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completed: usize,
    pub batches: usize,
    pub total_lanes: usize,
    pub occupied_lanes: usize,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
}

/// The scoring server: single executor thread draining an mpsc queue.
pub struct ScoringServer {
    jobs_tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<ServerStats>>,
}

impl ScoringServer {
    /// Start the server. `variant` picks the artifact family
    /// ("exact" | "prescored_k64" | ...).
    ///
    /// PJRT handles are not `Send`, so the registry is constructed *inside*
    /// the executor thread; artifact availability is pre-flighted here so
    /// misconfiguration fails fast on the caller.
    pub fn start(cfg: ServingConfig) -> Result<ScoringServer> {
        let (jobs_tx, jobs_rx): (Sender<Job>, Receiver<Job>) = channel();
        let dir = Path::new(&cfg.artifacts_dir).to_path_buf();
        let buckets = ArtifactRegistry::new(&dir, cfg.max_seq).available_batches(&cfg.variant);
        if buckets.is_empty() {
            anyhow::bail!(
                "no artifacts for variant '{}' in {} — run `make artifacts`",
                cfg.variant,
                dir.display()
            );
        }
        let handle = std::thread::spawn(move || {
            let mut registry = ArtifactRegistry::new(&dir, cfg.max_seq);
            // Pre-compile every bucket before accepting traffic.
            for &b in &buckets {
                if let Err(e) = registry.get_or_load(&cfg.variant, b) {
                    eprintln!("failed to compile artifact bucket {b}: {e:#}");
                }
            }
            run_loop(cfg, registry, buckets, jobs_rx)
        });
        Ok(ScoringServer { jobs_tx, handle: Some(handle) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.jobs_tx
            .send(Job { request, respond: tx })
            .expect("server thread gone");
        rx
    }

    /// Stop the server (drains the queue) and return final statistics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.jobs_tx);
        self.handle.take().unwrap().join().expect("server thread panicked")
    }
}

fn run_loop(
    cfg: ServingConfig,
    mut registry: ArtifactRegistry,
    buckets: Vec<usize>,
    jobs_rx: Receiver<Job>,
) -> ServerStats {
    let mut batcher = DynamicBatcher::new(BatcherConfig {
        buckets,
        max_batch_tokens: cfg.max_batch_tokens,
        max_seq: cfg.max_seq,
        deadline: Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3),
    });
    let mut responders: std::collections::HashMap<u64, Sender<Response>> = Default::default();
    let mut latency = LatencyStats::default();
    let mut completed = 0usize;
    let mut batches = 0usize;
    let mut total_lanes = 0usize;
    let mut occupied = 0usize;
    let mut scored_tokens = 0usize;
    let started = Instant::now();
    let mut open = true;

    while open || batcher.queue_len() > 0 {
        // Admit pending jobs (non-blocking drain, small wait when idle).
        loop {
            match jobs_rx.try_recv() {
                Ok(job) => {
                    responders.insert(job.request.id, job.respond);
                    batcher.push(job.request);
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let batch = match batcher.poll(Instant::now()) {
            Some(b) => b,
            None => {
                if !open && batcher.queue_len() > 0 {
                    // Shutdown: flush remainder.
                    match batcher.drain_all().into_iter().next() {
                        Some(b) => b,
                        None => continue,
                    }
                } else if open {
                    std::thread::sleep(Duration::from_micros(200));
                    continue;
                } else {
                    break;
                }
            }
        };
        execute_batch(
            &cfg,
            &mut registry,
            batch,
            &mut responders,
            &mut latency,
            &mut completed,
            &mut scored_tokens,
        );
        batches += 1;
    }

    // total_lanes/occupied were accumulated inside execute_batch via
    // closure-free design; recompute occupancy from counters we kept there.
    total_lanes = total_lanes.max(1);
    occupied = occupied.max(completed);
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    ServerStats {
        completed,
        batches,
        total_lanes,
        occupied_lanes: occupied,
        latency_p50_ms: latency.percentile(50.0),
        latency_p99_ms: latency.percentile(99.0),
        throughput_rps: completed as f64 / elapsed,
        tokens_per_s: scored_tokens as f64 / elapsed,
    }
}

fn execute_batch(
    cfg: &ServingConfig,
    registry: &mut ArtifactRegistry,
    batch: Batch,
    responders: &mut std::collections::HashMap<u64, Sender<Response>>,
    latency: &mut LatencyStats,
    completed: &mut usize,
    scored_tokens: &mut usize,
) {
    let lanes = batch.lanes;
    let rt = match registry.get_or_load(&cfg.variant, lanes) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact load failure: {e:#}");
            return;
        }
    };
    // Pad each request to max_seq with BOS (0); pad empty lanes with zeros.
    let mut tokens: Vec<Vec<u32>> = Vec::with_capacity(lanes);
    let mut lens: Vec<usize> = Vec::with_capacity(lanes);
    for req in &batch.requests {
        let mut row = req.tokens.clone();
        row.truncate(cfg.max_seq);
        lens.push(row.len());
        row.resize(cfg.max_seq, 0);
        tokens.push(row);
    }
    while tokens.len() < lanes {
        tokens.push(vec![0; cfg.max_seq]);
        lens.push(0);
    }
    match rt.execute(&tokens) {
        Ok(out) => {
            for (i, req) in batch.requests.iter().enumerate() {
                let valid = lens[i].saturating_sub(1);
                let nll = out.nll[i][..valid].to_vec();
                let lat = req.arrived.elapsed();
                latency.record(lat);
                *completed += 1;
                *scored_tokens += valid;
                if let Some(tx) = responders.remove(&req.id) {
                    let _ = tx.send(Response {
                        id: req.id,
                        nll,
                        generated: Vec::new(),
                        latency_ms: lat.as_secs_f64() * 1e3,
                        retained_keys: cfg.prescore_top_k,
                        fallback_used: false,
                    });
                }
            }
        }
        Err(e) => eprintln!("execute failure: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests require built artifacts and live in
    // rust/tests/integration_server.rs; unit coverage for the pieces lives
    // in coordinator::*.
}
