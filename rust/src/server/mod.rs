//! Thread-based serving loop (tokio substitute — see DESIGN.md).
//!
//! A `ScoringServer` owns the dynamic batcher and a pool of executor
//! workers. Clients submit requests over an mpsc channel and receive
//! responses over per-request channels. One coordinator thread blocks on
//! the job queue (`recv_timeout` against the batch deadline — no busy-wait
//! polling), forms batches, and hands them to a worker pool that drains a
//! shared batch queue; each worker owns its own [`ArtifactRegistry`] because
//! PJRT handles are not `Send`. Python is never on this path.
//!
//! Worker count: `ServingConfig::executor_workers`, with 0 meaning "derive
//! from the [`crate::parallel`] pool width" (i.e. `PALLAS_THREADS`), capped
//! so a laptop-sized pool doesn't compile one artifact registry per core.

use crate::attention::{AttentionBackend, AttentionSpec};
use crate::config::ServingConfig;
use crate::coordinator::{Batch, BatcherConfig, DynamicBatcher, Request, Response};
use crate::metrics::LatencyStats;
use crate::parallel;
use crate::runtime::ArtifactRegistry;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A submitted job: the request plus the channel to answer on.
pub struct Job {
    pub request: Request,
    pub respond: Sender<Response>,
}

/// Server statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completed: usize,
    pub batches: usize,
    pub total_lanes: usize,
    pub occupied_lanes: usize,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
    /// Executor workers that drained the batch queue.
    pub workers: usize,
    /// Attention kernel the server was configured with
    /// ([`crate::attention::AttnStats::kernel`]).
    pub kernel: String,
}

/// Mutable counters shared between the executor workers.
#[derive(Default)]
struct SharedStats {
    latency: LatencyStats,
    completed: usize,
    batches: usize,
    total_lanes: usize,
    occupied_lanes: usize,
    scored_tokens: usize,
}

/// A batch handed to the worker pool, with the responders for its requests
/// (aligned with `batch.requests`; `None` if a responder was lost, e.g. a
/// duplicate request id overwrote it — the batch still executes).
struct WorkItem {
    batch: Batch,
    responders: Vec<Option<Sender<Response>>>,
}

/// The scoring server: coordinator thread + executor worker pool.
pub struct ScoringServer {
    jobs_tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<ServerStats>>,
}

impl ScoringServer {
    /// Start the server. `variant` picks the artifact family
    /// ("exact" | "prescored_k64" | ...).
    ///
    /// PJRT handles are not `Send`, so each worker constructs its registry
    /// *inside* its own thread; artifact availability is pre-flighted here
    /// so misconfiguration fails fast on the caller.
    pub fn start(cfg: ServingConfig) -> Result<ScoringServer> {
        let (jobs_tx, jobs_rx): (Sender<Job>, Receiver<Job>) = channel();
        // Single construction path: [attention] spec (or the legacy-key
        // derivation) → backend. Misconfiguration fails fast here; the
        // backend is the source of per-request AttnStats, so the spec —
        // explicit or derived — must describe the kernel the artifact
        // variant actually executes (see validate_spec_for_variant), or the
        // reported stats would describe a kernel that never ran.
        let spec = cfg.attention_spec()?;
        validate_spec_for_variant(&spec, &cfg.variant)?;
        let backend: Box<dyn AttentionBackend> = spec.build();
        let dir = Path::new(&cfg.artifacts_dir).to_path_buf();
        let buckets = ArtifactRegistry::new(&dir, cfg.max_seq).available_batches(&cfg.variant);
        if buckets.is_empty() {
            anyhow::bail!(
                "no artifacts for variant '{}' in {} — run `make artifacts`",
                cfg.variant,
                dir.display()
            );
        }
        let handle = std::thread::spawn(move || run_loop(cfg, buckets, jobs_rx, backend));
        Ok(ScoringServer { jobs_tx, handle: Some(handle) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.jobs_tx
            .send(Job { request, respond: tx })
            .expect("server thread gone");
        rx
    }

    /// Stop the server (drains the queue) and return final statistics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.jobs_tx);
        self.handle.take().unwrap().join().expect("server thread panicked")
    }
}

/// Gate the attention spec (explicit `[attention] spec` or the legacy-key
/// derivation) against the artifact variant that actually executes
/// requests. Serving artifacts exist for two kernel families only: `exact`
/// artifacts run exact attention (an `exact` or `flash` spec), and
/// `prescored_k<K>` artifacts bake in Algorithm 2 with a fixed key budget K
/// (a `prescored:` spec whose `top_k` matches K). Other spec kernels
/// (`hyper:`, `restricted:`) run on the pure-Rust substrate (`ppl` CLI,
/// benches) but have no serving artifact. The δ-threshold and method are
/// not encoded in the variant name and cannot be cross-checked.
fn validate_spec_for_variant(spec: &AttentionSpec, variant: &str) -> Result<()> {
    if let Some(k) =
        variant.strip_prefix("prescored_k").and_then(|k| k.parse::<usize>().ok())
    {
        match spec {
            AttentionSpec::PreScored(cfg) if cfg.prescore.top_k == k => return Ok(()),
            AttentionSpec::PreScored(cfg) => anyhow::bail!(
                "attention spec retains top_k={} but artifact variant '{variant}' bakes \
                 in k={k} — per-request stats would misreport the retained budget \
                 (set [attention] spec / [prescore] top_k to match the variant)",
                cfg.prescore.top_k
            ),
            _ => {}
        }
    } else if variant.starts_with("prescored") {
        // Prescored family without a parseable budget: family check only.
        if matches!(spec, AttentionSpec::PreScored(_)) {
            return Ok(());
        }
    } else if matches!(spec, AttentionSpec::Exact | AttentionSpec::Flash { .. }) {
        return Ok(());
    }
    anyhow::bail!(
        "attention spec '{spec}' is inconsistent with artifact variant '{variant}': \
         exact artifacts serve exact/flash specs, prescored_k<K> artifacts serve \
         prescored specs with the matching top_k; hyper/restricted specs run on the \
         pure-Rust substrate (ppl CLI, benches) and have no serving artifact"
    )
}

/// Resolve the executor pool width from config / the global parallel pool.
fn worker_count(cfg: &ServingConfig) -> usize {
    if cfg.executor_workers > 0 {
        return cfg.executor_workers;
    }
    parallel::num_threads().clamp(1, 8)
}

fn run_loop(
    cfg: ServingConfig,
    buckets: Vec<usize>,
    jobs_rx: Receiver<Job>,
    backend: Box<dyn AttentionBackend>,
) -> ServerStats {
    let deadline = Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3);
    let mut batcher = DynamicBatcher::new(BatcherConfig {
        buckets: buckets.clone(),
        max_batch_tokens: cfg.max_batch_tokens,
        max_seq: cfg.max_seq,
        deadline,
    });
    let mut responders: HashMap<u64, Sender<Response>> = Default::default();
    let shared = Mutex::new(SharedStats::default());
    let workers = worker_count(&cfg);
    let (work_tx, work_rx) = channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let started = Instant::now();
    // The coordinator blocks on `recv_timeout` instead of sleep-polling:
    // with work queued it sleeps exactly to the oldest request's flush
    // deadline; idle it parks until the next submission (bounded so the
    // shutdown drain still makes progress).
    let idle_wait = Duration::from_millis(50);
    let min_wait = Duration::from_micros(50);

    std::thread::scope(|s| {
        for _ in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let shared = &shared;
            let cfg = &cfg;
            let buckets = &buckets;
            let backend = backend.as_ref();
            s.spawn(move || {
                // Per-worker registry (PJRT handles are not Send). Every
                // bucket is pre-compiled before the worker takes traffic.
                let mut registry =
                    ArtifactRegistry::new(Path::new(&cfg.artifacts_dir), cfg.max_seq);
                for &b in buckets {
                    if let Err(e) = registry.get_or_load(&cfg.variant, b) {
                        eprintln!("failed to compile artifact bucket {b}: {e:#}");
                    }
                }
                loop {
                    // Hold the lock only for the dequeue, never the execute.
                    let item = {
                        let rx = work_rx.lock().expect("work queue poisoned");
                        rx.recv()
                    };
                    match item {
                        Ok(item) => execute_batch(cfg, &mut registry, item, shared, backend),
                        Err(_) => break, // queue closed: drain complete
                    }
                }
            });
        }

        let mut open = true;
        while open || batcher.queue_len() > 0 {
            // Admit jobs: block until the next flush deadline (or a new
            // submission, whichever first), then drain whatever else is
            // already queued.
            let wait = batcher
                .time_to_deadline(Instant::now())
                .map(|d| d.clamp(min_wait, idle_wait))
                .unwrap_or(idle_wait);
            match jobs_rx.recv_timeout(wait) {
                Ok(job) => {
                    responders.insert(job.request.id, job.respond);
                    batcher.push(job.request);
                    loop {
                        match jobs_rx.try_recv() {
                            Ok(job) => {
                                responders.insert(job.request.id, job.respond);
                                batcher.push(job.request);
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => break,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
            // Ship every batch the policy allows right now.
            while let Some(batch) = batcher.poll(Instant::now()) {
                ship(batch, &mut responders, &work_tx);
            }
            if !open {
                for batch in batcher.drain_all() {
                    ship(batch, &mut responders, &work_tx);
                }
            }
        }
        // Close the batch queue: workers finish in-flight batches and exit;
        // the scope joins them before we assemble the final stats.
        drop(work_tx);
    });

    let stats = shared.into_inner().expect("stats poisoned");
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    ServerStats {
        completed: stats.completed,
        batches: stats.batches,
        total_lanes: stats.total_lanes.max(1),
        occupied_lanes: stats.occupied_lanes,
        latency_p50_ms: stats.latency.percentile(50.0),
        latency_p99_ms: stats.latency.percentile(99.0),
        throughput_rps: stats.completed as f64 / elapsed,
        tokens_per_s: stats.scored_tokens as f64 / elapsed,
        workers,
        kernel: backend.kernel_name().to_string(),
    }
}

/// Pair a formed batch with its responders and enqueue it for the pool.
fn ship(batch: Batch, responders: &mut HashMap<u64, Sender<Response>>, work_tx: &Sender<WorkItem>) {
    let txs: Vec<Option<Sender<Response>>> =
        batch.requests.iter().map(|req| responders.remove(&req.id)).collect();
    let _ = work_tx.send(WorkItem { batch, responders: txs });
}

fn execute_batch(
    cfg: &ServingConfig,
    registry: &mut ArtifactRegistry,
    item: WorkItem,
    shared: &Mutex<SharedStats>,
    backend: &dyn AttentionBackend,
) {
    let WorkItem { batch, responders } = item;
    let lanes = batch.lanes;
    let rt = match registry.get_or_load(&cfg.variant, lanes) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact load failure: {e:#}");
            return;
        }
    };
    // Pad each request to max_seq with BOS (0); pad empty lanes with zeros.
    let mut tokens: Vec<Vec<u32>> = Vec::with_capacity(lanes);
    let mut lens: Vec<usize> = Vec::with_capacity(lanes);
    for req in &batch.requests {
        let mut row = req.tokens.clone();
        row.truncate(cfg.max_seq);
        lens.push(row.len());
        row.resize(cfg.max_seq, 0);
        tokens.push(row);
    }
    while tokens.len() < lanes {
        tokens.push(vec![0; cfg.max_seq]);
        lens.push(0);
    }
    match rt.execute(&tokens) {
        Ok(out) => {
            let mut stats = shared.lock().expect("stats poisoned");
            stats.batches += 1;
            stats.total_lanes += lanes;
            stats.occupied_lanes += batch.requests.len();
            for (i, req) in batch.requests.iter().enumerate() {
                let valid = lens[i].saturating_sub(1);
                let nll = out.nll[i][..valid].to_vec();
                let lat = req.arrived.elapsed();
                stats.latency.record(lat);
                stats.completed += 1;
                stats.scored_tokens += valid;
                if let Some(tx) = &responders[i] {
                    // Real per-request stats from the backend this server is
                    // configured to serve (start() gates explicit specs
                    // against the artifact variant's family and key budget):
                    // the retention/fallback decision is a pure function of
                    // the context length and the backend config, so plan()
                    // reports what the kernel does for this request's
                    // context (previously hardcoded to cfg.prescore_top_k /
                    // false).
                    let attn = backend.plan(lens[i]);
                    let _ = tx.send(Response {
                        id: req.id,
                        nll,
                        generated: Vec::new(),
                        latency_ms: lat.as_secs_f64() * 1e3,
                        kernel: attn.kernel.to_string(),
                        retained_keys: attn.retained_keys,
                        fallback_used: attn.fallback_used,
                    });
                }
            }
        }
        Err(e) => eprintln!("execute failure: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    // End-to-end server tests require built artifacts and live in
    // rust/tests/integration_server.rs; unit coverage for the pieces lives
    // in coordinator::*.

    #[test]
    fn worker_count_respects_config_and_pool() {
        let pinned = ServingConfig { executor_workers: 3, ..Default::default() };
        assert_eq!(worker_count(&pinned), 3);
        let auto = ServingConfig { executor_workers: 0, ..Default::default() };
        let derived = crate::parallel::with_threads(5, || worker_count(&auto));
        assert_eq!(derived, 5);
        let capped = crate::parallel::with_threads(64, || worker_count(&auto));
        assert_eq!(capped, 8);
    }

    #[test]
    fn start_fails_fast_without_artifacts() {
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn start_fails_fast_on_bad_attention_spec() {
        // The spec pre-flight runs before the artifact scan, so a malformed
        // [attention] spec is rejected even without built artifacts.
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            attention_spec: "bogus:kernel".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("unknown attention kernel"));
    }

    #[test]
    fn start_rejects_spec_variant_mismatch() {
        // Response stats come from the configured backend; a spec that does
        // not describe the executing artifact would report stats for a
        // kernel that never ran.
        let base = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        // Wrong family: prescored spec on an exact artifact.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("inconsistent"), "{err:#}");
        // Right family, wrong baked-in budget.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // The gate also covers specs derived from the legacy [prescore]
        // keys — a [prescore] top_k that contradicts the variant is the
        // same misreporting bug.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            prescore_top_k: 128,
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // Unservable kernel: hyper has no artifact family at all.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "hyper:block=32".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("no serving artifact"), "{err:#}");
        // Consistent spec/variant pairs pass the gate (and fail later on
        // the missing artifacts instead).
        for (variant, spec) in
            [("prescored_k64", "prescored:kmeans,top_k=64"), ("exact", "flash")]
        {
            let cfg = ServingConfig {
                variant: variant.into(),
                attention_spec: spec.into(),
                ..base.clone()
            };
            let err = ScoringServer::start(cfg).err().expect("must fail");
            assert!(format!("{err:#}").contains("make artifacts"), "{variant}/{spec}: {err:#}");
        }
    }
}
