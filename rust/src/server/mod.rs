//! Thread-based serving loop (tokio substitute — see DESIGN.md).
//!
//! A `ScoringServer` owns the dynamic batcher, a pool of executor workers,
//! and — when a trained `weights.bin` is present — a pure-Rust **decode
//! engine**. Clients submit requests over an mpsc channel and receive
//! responses over per-request channels. One coordinator thread blocks on
//! the job queue (`recv_timeout` against the batch deadline — no busy-wait
//! polling), forms batches, and feeds a shared work queue that the executor
//! workers drain; each worker owns its own [`ArtifactRegistry`] because
//! PJRT handles are not `Send`. Python is never on this path.
//!
//! Two request classes flow through the same worker pool:
//!
//! * **Scoring** (`generate == 0`) — dynamic batches executed against the
//!   AOT artifacts (or, when no artifact is loadable but the substrate
//!   model is, scored by the pure-Rust transformer).
//! * **Generation** (`generate > 0`) — routed to the decode engine: one
//!   prefill on the transformer substrate captures per-layer/head KV caches
//!   and attention [`crate::attention::DecodeState`]s, then the
//!   prefill/decode [`Scheduler`] dispatches decode *rounds*
//!   ([`Scheduler::next_round`]) that step each sequence through the
//!   backends' `decode_step` against the block-allocated
//!   [`KvCacheManager`] — prefill is never re-run, so a decode step costs
//!   selection-sized work for `prescored:`/`restricted:` specs instead of
//!   O(n²). Workers re-pump the scheduler after every round, so decode
//!   throughput is not gated on the coordinator's batching deadline, and
//!   the scheduler's starvation bound (observable via
//!   [`ServerStats::decode_rounds`] and the per-step percentiles) keeps
//!   decode latency bounded under prefill pressure.
//!
//! Worker count: `ServingConfig::executor_workers`, with 0 meaning "derive
//! from the [`crate::parallel`] pool width" (i.e. `PALLAS_THREADS`), capped
//! so a laptop-sized pool doesn't compile one artifact registry per core.

use crate::attention::{AttentionBackend, AttentionSpec, AttnPolicy};
use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, PrefixHit, PrefixSnapshot};
use crate::config::ServingConfig;
use crate::coordinator::{
    Batch, BatcherConfig, DynamicBatcher, KvCacheManager, PreScoreManager,
    PreScoreManagerConfig, Request, Response, Scheduler, SchedulerConfig, WorkItem,
};
use crate::metrics::LatencyStats;
use crate::model::transformer::{argmax_row, nll_entry, nll_from_logits};
use crate::model::{DecodeSession, Transformer, TransformerConfig, WeightStore};
use crate::parallel;
use crate::runtime::ArtifactRegistry;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A submitted job: the request plus the channel to answer on.
pub struct Job {
    pub request: Request,
    pub respond: Sender<Response>,
}

/// Server statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub completed: usize,
    pub batches: usize,
    pub total_lanes: usize,
    pub occupied_lanes: usize,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
    /// Executor workers that drained the work queue.
    pub workers: usize,
    /// Attention kernel the server was configured with
    /// ([`crate::attention::AttnStats::kernel`]).
    pub kernel: String,
    /// Prefill executions (scoring batches + decode-engine prefills).
    pub prefills: usize,
    /// Decode rounds dispatched by the scheduler.
    pub decode_rounds: usize,
    /// Individual decode steps executed across all sequences.
    pub decode_steps: usize,
    /// Per-decode-step wall time percentiles (ms) — the starvation-bound
    /// observability the scheduler's policy is judged by.
    pub decode_step_p50_ms: f64,
    pub decode_step_p99_ms: f64,
    /// Shared-prefix cache accounting (all zero when the cache is disabled
    /// or the spec is not prefix-cacheable). `prefix_hit_tokens` counts
    /// prefill tokens served from the cache — forward/pre-scoring work the
    /// warm path never performed.
    pub prefix_hits: usize,
    pub prefix_misses: usize,
    pub prefix_hit_tokens: usize,
    pub prefix_insertions: usize,
    pub prefix_evictions: usize,
    pub prefix_nodes: usize,
    pub prefix_cached_tokens: usize,
}

/// Mutable counters shared between the executor workers.
#[derive(Default)]
struct SharedStats {
    latency: LatencyStats,
    decode_step_latency: LatencyStats,
    completed: usize,
    batches: usize,
    total_lanes: usize,
    occupied_lanes: usize,
    scored_tokens: usize,
    prefills: usize,
    decode_rounds: usize,
    decode_steps: usize,
}

/// Work drained by the executor pool.
enum Work {
    /// Artifact-scored batch with the responders for its requests (aligned
    /// with `batch.requests`; `None` if a responder was lost, e.g. a
    /// duplicate request id overwrote it — the batch still executes).
    Score { batch: Batch, responders: Vec<Option<Sender<Response>>> },
    /// A prefill/decode round from the decode engine's scheduler.
    Gen(WorkItem),
}

/// Shared work queue (in-process channel) feeding the executor workers.
/// Workers both consume from and (for decode-round re-pumping) produce into
/// it, so it is a mutex/condvar queue rather than an mpsc channel — close()
/// plus an emptiness/engine-idle predicate replaces sender counting.
struct WorkQueue {
    state: Mutex<(VecDeque<Work>, bool)>,
    cv: Condvar,
}

impl WorkQueue {
    fn new() -> WorkQueue {
        WorkQueue { state: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() }
    }

    fn push(&self, w: Work) {
        let mut g = self.state.lock().expect("work queue poisoned");
        g.0.push_back(w);
        self.cv.notify_one();
    }

    fn close(&self) {
        let mut g = self.state.lock().expect("work queue poisoned");
        g.1 = true;
        self.cv.notify_all();
    }

    /// Blocking pop. Returns `None` once the queue is closed, empty, and
    /// `drained()` reports no in-flight engine work (a finishing decode
    /// round may still re-pump new items after close). `drained()` takes
    /// the engine mutex, so it is evaluated *outside* the queue lock —
    /// pushes never stall behind it.
    fn pop<F: Fn() -> bool>(&self, drained: F) -> Option<Work> {
        loop {
            let closed = {
                let mut g = self.state.lock().expect("work queue poisoned");
                loop {
                    if let Some(w) = g.0.pop_front() {
                        return Some(w);
                    }
                    if g.1 {
                        break true;
                    }
                    let (ng, _) = self
                        .cv
                        .wait_timeout(g, Duration::from_millis(25))
                        .expect("work queue poisoned");
                    g = ng;
                }
            };
            debug_assert!(closed);
            if drained() {
                // Re-check under the lock: a decode round finishing between
                // the checks may have re-pumped one last item.
                let g = self.state.lock().expect("work queue poisoned");
                if g.0.is_empty() {
                    return None;
                }
                continue;
            }
            // Closed but engine still streaming: pace the re-check.
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// One live generation sequence inside the decode engine.
struct GenSession {
    sess: DecodeSession,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    /// Prefill NLL (scored from the prefill logits — no extra forward).
    nll: Vec<f32>,
    target_new: usize,
    generated: Vec<u32>,
    next_token: u32,
    decode_ms: f64,
    /// Pinned prefix-cache node this session branched from (released on
    /// finish so LRU eviction can reclaim cold prefixes).
    cache_pin: Option<usize>,
}

/// Everything a prefill needs, cloned out of the engine under its lock so
/// the (long) forward runs lock-free: the immutable model/policy handles,
/// the request, and the prefix-cache hit if any.
struct PrefillPrep {
    id: u64,
    tokens: Vec<u32>,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    generate: usize,
    hit: Option<PrefixHit>,
    model: Arc<Transformer>,
    policy: Arc<AttnPolicy>,
    /// Snapshot the (extended) prefix into the cache afterwards?
    want_snapshot: bool,
}

/// Result of the lock-free prefill compute, applied back under the lock.
struct PrefillOutcome {
    id: u64,
    respond: Option<Sender<Response>>,
    arrived: Instant,
    generate: usize,
    result: Result<PrefillDone>,
}

struct PrefillDone {
    sess: DecodeSession,
    nll: Vec<f32>,
    next_token: u32,
    snapshot: Option<(Vec<u32>, PrefixSnapshot)>,
    /// Pinned cache node of the warm hit this prefill branched from.
    cache_pin: Option<usize>,
}

/// Pure-Rust decode engine: prefill once on the transformer substrate, then
/// stream tokens through the attention backends' `decode_step` against the
/// block-allocated KV cache. The engine is a single mutex-guarded state
/// machine (sessions step sequentially within a round); the decode kernels
/// themselves shard across the persistent [`crate::parallel`] pool.
struct DecodeEngine {
    /// Immutable model/policy behind `Arc` so prefills and substrate scoring
    /// clone a handle out of a brief lock and run the forward lock-free —
    /// a long scoring forward can no longer stall decode rounds.
    model: Arc<Transformer>,
    policy: Arc<AttnPolicy>,
    manager: PreScoreManager,
    kv: KvCacheManager,
    scheduler: Scheduler,
    /// Shared-prefix cache (None when disabled or the spec's artifacts are
    /// not prefix-reusable).
    cache: Option<PrefixCache>,
    /// Partial-prefix hits allowed? Only for suffix-stable kernels
    /// (exact/flash, and `prescored:...,mode=stream` whose streaming
    /// selection makes prefix rows length-invariant); the remaining
    /// rank/selection kernels dedup at full length only — see
    /// `AttentionSpec::suffix_stable`.
    suffix_stable: bool,
    /// Admitted but not yet prefilled.
    pending: HashMap<u64, Job>,
    /// Request ids whose prefill is computing outside the lock. Keeps
    /// `active()` truthful for the shutdown drain AND guards the duplicate
    /// check: a re-submitted id must not reach `kv.admit` (which asserts
    /// single admission) while the first prefill is mid-flight.
    in_flight: std::collections::HashSet<u64>,
    /// Prefilled, streaming tokens.
    sessions: HashMap<u64, GenSession>,
    max_new: usize,
    kernel: &'static str,
}

impl DecodeEngine {
    fn new(model: Transformer, cfg: &ServingConfig, spec: &AttentionSpec) -> DecodeEngine {
        let mut manager_cfg = PreScoreManagerConfig::from_serving(cfg).unwrap_or_else(|e| {
            // A bad [prescore] method must not silently change the decode
            // refresh cadence — keep the configured period on fallback.
            eprintln!("decode engine: {e:#}; using default prescore policy");
            PreScoreManagerConfig {
                refresh_every: cfg.prescore_refresh_every,
                ..Default::default()
            }
        });
        // One refresh policy end to end: selection-cached specs own their
        // period (`prescored:` via `refresh=` / the legacy-key derivation,
        // `restricted:` via its `refresh=` key); the legacy
        // `[prescore] refresh_every` only applies to specs without one. The
        // manager drives both the states (set_refresh_every at prefill) and
        // the KV-cache selection-mirror cadence, so they can never drift.
        match spec {
            AttentionSpec::PreScored(ps) => {
                manager_cfg.refresh_every = ps.decode_refresh_every;
                manager_cfg.top_k = ps.prescore.top_k;
                manager_cfg.fallback_delta = ps.fallback_delta;
            }
            AttentionSpec::Restricted { refresh, .. }
                if *refresh != crate::attention::decode::RESTRICTED_REFRESH_DEFAULT =>
            {
                // Previously set_refresh_every stomped the spec's period
                // with the legacy key at prefill — the serving half of the
                // "refresh unreachable from the restricted grammar" bug.
                // Only a non-default `refresh=` wins: an omitted key is
                // indistinguishable from the default, and existing configs
                // that steer restricted cadence via `[prescore]
                // refresh_every` must keep working.
                manager_cfg.refresh_every = *refresh;
            }
            _ => {}
        }
        let slots = model.cfg.n_layers * model.cfg.n_heads;
        let model = Arc::new(model);
        let policy = Arc::new(AttnPolicy::uniform(spec.clone()));
        let cache = if cfg.prefix_cache_blocks > 0 && spec.prefix_cacheable() {
            let persist_path = if cfg.prefix_persist_path.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.prefix_persist_path))
            };
            let mut cache = PrefixCache::new(PrefixCacheConfig {
                blocks: cfg.prefix_cache_blocks,
                min_tokens: cfg.prefix_min_tokens,
                persist_path,
            });
            if let Some(p) = cache.config().persist_path.clone() {
                if p.exists() {
                    match crate::cache::persist::load(
                        &mut cache,
                        &policy,
                        model.cfg.n_heads,
                        slots,
                        model.cfg.d_head(),
                        model.cfg.vocab,
                        &p,
                    ) {
                        Ok(n) => eprintln!(
                            "prefix cache: restored {n} prefixes from {}",
                            p.display()
                        ),
                        Err(e) => eprintln!(
                            "prefix cache: ignoring {}: {e:#}",
                            p.display()
                        ),
                    }
                }
            }
            Some(cache)
        } else {
            if cfg.prefix_cache_blocks > 0 {
                eprintln!(
                    "prefix cache disabled: spec '{spec}' has no prefix-reusable artifacts"
                );
            }
            None
        };
        DecodeEngine {
            kv: KvCacheManager::new(cfg.kv_blocks, slots),
            manager: PreScoreManager::new(manager_cfg),
            scheduler: Scheduler::new(SchedulerConfig::default()),
            policy,
            cache,
            suffix_stable: spec.suffix_stable(),
            pending: HashMap::new(),
            in_flight: std::collections::HashSet::new(),
            sessions: HashMap::new(),
            max_new: cfg.decode_max_new,
            kernel: spec.kernel_name(),
            model,
        }
    }

    /// Anything admitted, mid-prefill, or streaming (work may still be in
    /// flight even when the scheduler queues are momentarily empty).
    fn active(&self) -> bool {
        !self.pending.is_empty() || !self.in_flight.is_empty() || !self.sessions.is_empty()
    }

    fn admit(&mut self, job: Job) {
        let id = job.request.id;
        self.pending.insert(id, job);
        self.scheduler.submit_prefill(vec![id]);
    }

    fn next_round(&mut self, free_workers: usize) -> Vec<WorkItem> {
        self.scheduler.next_round(free_workers)
    }

    /// Per-layer·head selections snapshot for the KV-cache manager.
    fn selections_snapshot(sess: &DecodeSession) -> Vec<Vec<usize>> {
        sess.states()
            .iter()
            .map(|s| s.selection().map(|x| x.to_vec()).unwrap_or_default())
            .collect()
    }

    /// Phase 1 of a prefill, under the engine lock: admission checks, KV
    /// page reservation, and the prefix-cache walk. Returns the lock-free
    /// compute's input (`None` = dropped, duplicate, or requeued).
    fn prepare_prefill(&mut self, id: u64) -> Option<PrefillPrep> {
        let job = self.pending.remove(&id)?;
        if self.sessions.contains_key(&id) || self.in_flight.contains(&id) {
            // Duplicate request id while the first is still streaming (or
            // still computing its prefill outside the lock): the newer
            // responder is dropped (same policy as the scoring path's
            // responder map). The in-flight check matters because
            // `kv.admit` asserts single admission.
            return None;
        }
        let mut tokens = job.request.tokens.clone();
        tokens.truncate(self.model.cfg.max_seq);
        if tokens.is_empty() {
            return None; // responder dropped → caller observes disconnect
        }
        let need_pages = crate::coordinator::kv_cache::pages_for(tokens.len());
        if need_pages > self.kv.capacity() {
            eprintln!(
                "request {id} needs {need_pages} kv pages but the pool holds {} — dropping",
                self.kv.capacity()
            );
            return None;
        }
        if self.kv.admit(id, tokens.len()).is_none() {
            // Pool momentarily exhausted by live sequences: requeue the
            // prefill — pages free as sequences finish, and the scheduler's
            // prefill-priority keeps retrying at the pump cadence.
            self.pending.insert(id, job);
            self.scheduler.submit_prefill(vec![id]);
            return None;
        }
        // Walk the shared-prefix tree; a hit clones the cached KV/artifacts
        // out (copy-on-write branch) and pins the node until finish().
        // Non-suffix-stable kernels only dedup full-length matches.
        let full_only = !self.suffix_stable;
        let hit = self.cache.as_mut().and_then(|c| c.lookup(&tokens, full_only));
        let cached = hit.as_ref().map_or(0, |h| h.len);
        let want_snapshot = self
            .cache
            .as_ref()
            .map_or(false, |c| c.wants_insert(&tokens, cached, full_only));
        self.in_flight.insert(id);
        let Job { request, respond } = job;
        Some(PrefillPrep {
            id,
            tokens,
            respond: Some(respond),
            arrived: request.arrived,
            generate: request.generate,
            hit,
            model: Arc::clone(&self.model),
            policy: Arc::clone(&self.policy),
            want_snapshot,
        })
    }

    /// Phase 3, back under the lock: install the session, mirror the
    /// selections into the KV manager, and snapshot the prefix into the
    /// cache.
    fn complete_prefill(&mut self, outcome: PrefillOutcome, shared: &Mutex<SharedStats>) {
        let PrefillOutcome { id, respond, arrived, generate, result } = outcome;
        self.in_flight.remove(&id);
        match result {
            Ok(done) => {
                let PrefillDone { mut sess, nll, next_token, snapshot, cache_pin } = done;
                sess.set_refresh_every(self.manager.cfg.refresh_every);
                let unique_chain = !self.suffix_stable;
                if let (Some(cache), Some((tokens, snap))) = (self.cache.as_mut(), snapshot) {
                    cache.insert(&tokens, snap, unique_chain);
                }
                self.kv.set_selections(id, Self::selections_snapshot(&sess));
                shared.lock().expect("stats poisoned").prefills += 1;
                self.sessions.insert(
                    id,
                    GenSession {
                        sess,
                        respond,
                        arrived,
                        nll,
                        target_new: generate.min(self.max_new),
                        generated: Vec::new(),
                        next_token,
                        decode_ms: 0.0,
                        cache_pin,
                    },
                );
                self.scheduler.submit_decode(id);
            }
            Err(e) => {
                eprintln!("decode prefill failed for request {id}: {e:#}");
                self.kv.evict(id);
            }
        }
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Persist the artifact store on shutdown (no-op without a path).
    fn save_cache(&self) {
        let Some(cache) = self.cache.as_ref() else { return };
        let Some(path) = cache.config().persist_path.clone() else { return };
        // Non-suffix-stable policies must not persist mixed-donor chains
        // (lookup refuses them; a reload would launder the mix).
        let uniform_only = !self.suffix_stable;
        if let Err(e) = crate::cache::persist::save(
            cache,
            &self.policy,
            self.model.cfg.n_heads,
            uniform_only,
            &path,
        ) {
            eprintln!("prefix cache persist failed: {e:#}");
        }
    }

    /// One decode round: a single token step for each scheduled sequence.
    fn run_decode(&mut self, ids: &[u64], shared: &Mutex<SharedStats>) {
        let max_seq = self.model.cfg.max_seq;
        let mut step_ms: Vec<f64> = Vec::with_capacity(ids.len());
        for &id in ids {
            let done = {
                let Some(s) = self.sessions.get_mut(&id) else { continue };
                if s.generated.len() >= s.target_new || s.sess.pos() >= max_seq {
                    true
                } else if self.kv.append_token(id).is_none() {
                    eprintln!("kv cache exhausted for sequence {id}; finishing early");
                    true
                } else {
                    let t0 = Instant::now();
                    let token = s.next_token;
                    s.generated.push(token);
                    let row = self.model.decode_token(&mut s.sess, token, &self.policy);
                    s.next_token = argmax_row(&row);
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    s.decode_ms += ms;
                    step_ms.push(ms);
                    // Keep the cache's selection view fresh at the refresh
                    // cadence (the states refresh themselves; this mirrors
                    // the result into the kv manager's selection sets).
                    if self.manager.needs_refresh(self.kv.steps_since_refresh(id)) {
                        let snap = Self::selections_snapshot(&s.sess);
                        self.kv.set_selections(id, snap);
                    }
                    s.generated.len() >= s.target_new || s.sess.pos() >= max_seq
                }
            };
            if done {
                self.finish(id, shared);
            } else {
                self.scheduler.submit_decode(id);
            }
        }
        let mut st = shared.lock().expect("stats poisoned");
        st.decode_rounds += 1;
        for ms in step_ms {
            st.decode_step_latency.record_ms(ms);
            st.decode_steps += 1;
        }
    }

    fn finish(&mut self, id: u64, shared: &Mutex<SharedStats>) {
        let Some(s) = self.sessions.remove(&id) else { return };
        self.kv.evict(id);
        if let (Some(pin), Some(cache)) = (s.cache_pin, self.cache.as_mut()) {
            cache.release(pin);
        }
        let lat = s.arrived.elapsed();
        let context = s.sess.pos();
        let retained = s.sess.min_retained().unwrap_or(context);
        let fallback = s.sess.states().iter().any(|st| st.fallback_used());
        {
            let mut st = shared.lock().expect("stats poisoned");
            st.latency.record(lat);
            st.completed += 1;
            st.scored_tokens += s.nll.len() + s.generated.len();
        }
        if let Some(tx) = s.respond {
            let decode_steps = s.generated.len();
            let _ = tx.send(Response {
                id,
                nll: s.nll,
                generated: s.generated,
                latency_ms: lat.as_secs_f64() * 1e3,
                kernel: self.kernel.to_string(),
                retained_keys: retained,
                fallback_used: fallback,
                decode_steps,
                decode_ms: s.decode_ms,
            });
        }
    }
}

/// The scoring server: coordinator thread + executor worker pool.
pub struct ScoringServer {
    jobs_tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<ServerStats>>,
}

impl ScoringServer {
    /// Start the server. `variant` picks the artifact family
    /// ("exact" | "prescored_k64" | ...).
    ///
    /// PJRT handles are not `Send`, so each worker constructs its registry
    /// *inside* its own thread; artifact availability is pre-flighted here
    /// so misconfiguration fails fast on the caller. When the artifacts
    /// directory holds a trained `weights.bin`, the pure-Rust decode engine
    /// is enabled for generation requests (and as the scoring fallback when
    /// no artifact is loadable).
    pub fn start(cfg: ServingConfig) -> Result<ScoringServer> {
        let model = load_substrate_model(&cfg);
        Self::start_inner(cfg, model)
    }

    /// Start with an explicit substrate model (tests / embedded use): the
    /// decode engine runs on `model`, and artifacts are optional — with no
    /// artifacts, scoring requests are served by the substrate too.
    pub fn start_with_model(cfg: ServingConfig, model: Transformer) -> Result<ScoringServer> {
        Self::start_inner(cfg, Some(model))
    }

    fn start_inner(cfg: ServingConfig, model: Option<Transformer>) -> Result<ScoringServer> {
        let (jobs_tx, jobs_rx): (Sender<Job>, Receiver<Job>) = channel();
        // Single construction path: [attention] spec (or the legacy-key
        // derivation) → backend. Misconfiguration fails fast here; the
        // backend is the source of per-request AttnStats, so the spec —
        // explicit or derived — must describe the kernel the artifact
        // variant actually executes (see validate_spec_for_variant), or the
        // reported stats would describe a kernel that never ran.
        let spec = cfg.attention_spec()?;
        let dir = Path::new(&cfg.artifacts_dir).to_path_buf();
        let buckets = ArtifactRegistry::new(&dir, cfg.max_seq).available_batches(&cfg.variant);
        // Substrate-only serving (model, no artifacts) runs any spec; once
        // artifacts execute requests the spec must describe them.
        if !(buckets.is_empty() && model.is_some()) {
            validate_spec_for_variant(&spec, &cfg.variant)?;
        }
        if buckets.is_empty() && model.is_none() {
            anyhow::bail!(
                "no artifacts for variant '{}' in {} — run `make artifacts`",
                cfg.variant,
                dir.display()
            );
        }
        let backend: Box<dyn AttentionBackend> = spec.build();
        let handle =
            std::thread::spawn(move || run_loop(cfg, buckets, jobs_rx, backend, spec, model));
        Ok(ScoringServer { jobs_tx, handle: Some(handle) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.jobs_tx
            .send(Job { request, respond: tx })
            .expect("server thread gone");
        rx
    }

    /// Stop the server (drains the queue) and return final statistics.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.jobs_tx);
        self.handle.take().unwrap().join().expect("server thread panicked")
    }
}

/// Load the pure-Rust substrate model from `weights.bin` if present.
fn load_substrate_model(cfg: &ServingConfig) -> Option<Transformer> {
    let path = Path::new(&cfg.artifacts_dir).join("weights.bin");
    if !path.exists() {
        return None;
    }
    match WeightStore::load(&path) {
        Ok(ws) => Some(Transformer::from_weights(&ws, TransformerConfig::default())),
        Err(e) => {
            eprintln!("failed to load substrate weights {}: {e:#}", path.display());
            None
        }
    }
}

/// Gate the attention spec (explicit `[attention] spec` or the legacy-key
/// derivation) against the artifact variant that actually executes
/// requests. Serving artifacts exist for two kernel families only: `exact`
/// artifacts run exact attention (an `exact` or `flash` spec), and
/// `prescored_k<K>` artifacts bake in Algorithm 2 with a fixed key budget K
/// (a `prescored:` spec whose `top_k` matches K). Other spec kernels
/// (`hyper:`, `restricted:`) run on the pure-Rust substrate (`ppl` CLI,
/// benches, the substrate-only server mode) but have no serving artifact.
/// The δ-threshold and method are not encoded in the variant name and
/// cannot be cross-checked.
fn validate_spec_for_variant(spec: &AttentionSpec, variant: &str) -> Result<()> {
    use crate::attention::PreScoreMode;
    // Streaming pre-scoring is a substrate-only kernel: the prescored_k<K>
    // artifacts bake in the full-recluster Algorithm 2, so a stream spec
    // would misdescribe what executes (substrate-only servers skip this
    // gate entirely and serve stream specs end to end).
    if let AttentionSpec::PreScored(cfg) = spec {
        if cfg.mode == PreScoreMode::Stream && variant.starts_with("prescored") {
            anyhow::bail!(
                "attention spec '{spec}' uses mode=stream, which has no serving \
                 artifact — prescored_k<K> artifacts bake in the full re-cluster; \
                 stream specs run on the pure-Rust substrate (weights.bin) only"
            );
        }
    }
    if let Some(k) =
        variant.strip_prefix("prescored_k").and_then(|k| k.parse::<usize>().ok())
    {
        match spec {
            AttentionSpec::PreScored(cfg) if cfg.prescore.top_k == k => return Ok(()),
            AttentionSpec::PreScored(cfg) => anyhow::bail!(
                "attention spec retains top_k={} but artifact variant '{variant}' bakes \
                 in k={k} — per-request stats would misreport the retained budget \
                 (set [attention] spec / [prescore] top_k to match the variant)",
                cfg.prescore.top_k
            ),
            _ => {}
        }
    } else if variant.starts_with("prescored") {
        // Prescored family without a parseable budget: family check only.
        if matches!(spec, AttentionSpec::PreScored(_)) {
            return Ok(());
        }
    } else if matches!(spec, AttentionSpec::Exact | AttentionSpec::Flash { .. }) {
        return Ok(());
    }
    anyhow::bail!(
        "attention spec '{spec}' is inconsistent with artifact variant '{variant}': \
         exact artifacts serve exact/flash specs, prescored_k<K> artifacts serve \
         prescored specs with the matching top_k; hyper/restricted specs run on the \
         pure-Rust substrate (ppl CLI, benches) and have no serving artifact"
    )
}

/// Resolve the executor pool width from config / the global parallel pool.
fn worker_count(cfg: &ServingConfig) -> usize {
    if cfg.executor_workers > 0 {
        return cfg.executor_workers;
    }
    parallel::num_threads().clamp(1, 8)
}

fn run_loop(
    cfg: ServingConfig,
    buckets: Vec<usize>,
    jobs_rx: Receiver<Job>,
    backend: Box<dyn AttentionBackend>,
    spec: AttentionSpec,
    model: Option<Transformer>,
) -> ServerStats {
    let deadline = Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3);
    // Substrate-only mode has no compiled lane buckets; batch up to the
    // configured batch size on the model path instead.
    let lane_buckets =
        if buckets.is_empty() { vec![cfg.batch_size.max(1)] } else { buckets.clone() };
    let mut batcher = DynamicBatcher::new(BatcherConfig {
        buckets: lane_buckets,
        max_batch_tokens: cfg.max_batch_tokens,
        max_seq: cfg.max_seq,
        deadline,
    });
    let engine: Option<Mutex<DecodeEngine>> =
        model.map(|m| Mutex::new(DecodeEngine::new(m, &cfg, &spec)));
    let mut responders: HashMap<u64, Sender<Response>> = Default::default();
    let shared = Mutex::new(SharedStats::default());
    let workers = worker_count(&cfg);
    let queue = WorkQueue::new();
    let started = Instant::now();
    // The coordinator blocks on `recv_timeout` instead of sleep-polling:
    // with work queued it sleeps exactly to the oldest request's flush
    // deadline; idle it parks until the next submission (bounded so the
    // shutdown drain still makes progress). Decode rounds are re-pumped by
    // the workers themselves, so decode cadence never waits on this loop.
    let idle_wait = Duration::from_millis(50);
    let min_wait = Duration::from_micros(50);

    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = &queue;
            let shared = &shared;
            let cfg = &cfg;
            let buckets = &buckets;
            let backend = backend.as_ref();
            let engine = engine.as_ref();
            s.spawn(move || {
                // Per-worker registry (PJRT handles are not Send). Every
                // bucket is pre-compiled before the worker takes traffic.
                let mut registry =
                    ArtifactRegistry::new(Path::new(&cfg.artifacts_dir), cfg.max_seq);
                for &b in buckets {
                    if let Err(e) = registry.get_or_load(&cfg.variant, b) {
                        eprintln!("failed to compile artifact bucket {b}: {e:#}");
                    }
                }
                let drained =
                    || engine.map_or(true, |e| !e.lock().expect("engine poisoned").active());
                while let Some(work) = queue.pop(&drained) {
                    match work {
                        Work::Score { batch, responders } => execute_batch(
                            cfg,
                            &mut registry,
                            batch,
                            responders,
                            shared,
                            backend,
                            engine,
                        ),
                        Work::Gen(item) => {
                            let eng = engine.expect("gen work without engine");
                            execute_gen(item, eng, shared);
                            // Re-pump: keep decode rounds flowing without
                            // waiting for the coordinator's next wake.
                            let follow =
                                eng.lock().expect("engine poisoned").next_round(1);
                            for it in follow {
                                queue.push(Work::Gen(it));
                            }
                        }
                    }
                }
            });
        }

        let engine_active = || {
            engine
                .as_ref()
                .map_or(false, |e| e.lock().expect("engine poisoned").active())
        };
        let mut open = true;
        while open || batcher.queue_len() > 0 || engine_active() {
            // Admit jobs: block until the next flush deadline (or a new
            // submission, whichever first), then drain whatever else is
            // already queued.
            let wait = batcher
                .time_to_deadline(Instant::now())
                .map(|d| d.clamp(min_wait, idle_wait))
                .unwrap_or(idle_wait);
            let route = |job: Job,
                             responders: &mut HashMap<u64, Sender<Response>>,
                             batcher: &mut DynamicBatcher| {
                if job.request.generate > 0 {
                    match engine.as_ref() {
                        Some(e) => e.lock().expect("engine poisoned").admit(job),
                        None => {
                            // Fail explicitly (dropped responder) rather than
                            // silently serving a generation request as
                            // scoring-only.
                            eprintln!(
                                "request {} asks for {} generated tokens but this \
                                 server has no substrate model (weights.bin) — \
                                 dropping",
                                job.request.id, job.request.generate
                            );
                        }
                    }
                    return;
                }
                responders.insert(job.request.id, job.respond);
                batcher.push(job.request);
            };
            if open {
                match jobs_rx.recv_timeout(wait) {
                    Ok(job) => {
                        route(job, &mut responders, &mut batcher);
                        loop {
                            match jobs_rx.try_recv() {
                                Ok(job) => route(job, &mut responders, &mut batcher),
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => open = false,
                }
            } else {
                // Shutdown drain: no new jobs can arrive; pace the loop
                // while in-flight decode sequences finish.
                std::thread::sleep(Duration::from_millis(2));
            }
            // Ship every batch the policy allows right now.
            while let Some(batch) = batcher.poll(Instant::now()) {
                ship(batch, &mut responders, &queue);
            }
            if !open {
                for batch in batcher.drain_all() {
                    ship(batch, &mut responders, &queue);
                }
            }
            // Seed engine rounds (workers keep them flowing afterwards).
            if let Some(e) = engine.as_ref() {
                let round = e.lock().expect("engine poisoned").next_round(workers);
                for it in round {
                    queue.push(Work::Gen(it));
                }
            }
        }
        // Close the work queue: workers finish in-flight work (including
        // decode rounds still re-pumping) and exit; the scope joins them
        // before we assemble the final stats.
        queue.close();
    });

    // Final prefix-cache accounting + persistence (the engine is exclusively
    // ours again once the scope has joined every worker).
    let prefix = match engine {
        Some(e) => {
            let eng = e.into_inner().expect("engine poisoned");
            eng.save_cache();
            eng.cache_stats()
        }
        None => CacheStats::default(),
    };
    let stats = shared.into_inner().expect("stats poisoned");
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    ServerStats {
        completed: stats.completed,
        batches: stats.batches,
        total_lanes: stats.total_lanes.max(1),
        occupied_lanes: stats.occupied_lanes,
        latency_p50_ms: stats.latency.percentile(50.0),
        latency_p99_ms: stats.latency.percentile(99.0),
        throughput_rps: stats.completed as f64 / elapsed,
        tokens_per_s: stats.scored_tokens as f64 / elapsed,
        workers,
        kernel: backend.kernel_name().to_string(),
        prefills: stats.prefills,
        decode_rounds: stats.decode_rounds,
        decode_steps: stats.decode_steps,
        decode_step_p50_ms: stats.decode_step_latency.percentile(50.0),
        decode_step_p99_ms: stats.decode_step_latency.percentile(99.0),
        prefix_hits: prefix.hits,
        prefix_misses: prefix.misses,
        prefix_hit_tokens: prefix.hit_tokens,
        prefix_insertions: prefix.insertions,
        prefix_evictions: prefix.evictions,
        prefix_nodes: prefix.nodes,
        prefix_cached_tokens: prefix.cached_tokens,
    }
}

/// Pair a formed batch with its responders and enqueue it for the pool.
fn ship(batch: Batch, responders: &mut HashMap<u64, Sender<Response>>, queue: &WorkQueue) {
    let txs: Vec<Option<Sender<Response>>> =
        batch.requests.iter().map(|req| responders.remove(&req.id)).collect();
    queue.push(Work::Score { batch, responders: txs });
}

/// Phase 2 of a prefill — the forward itself, run WITHOUT the engine lock
/// (model/policy are `Arc` handles) so decode rounds keep flowing while a
/// long prefill computes.
///
/// Warm path: rebuild the session from the cache hit, stitch the boundary
/// NLL entry from the cached logits row, and push only the un-cached suffix
/// through `resume_decode` — O(suffix) forward work, bitwise-identical
/// logits/NLL to the cold path. Cold path: full `begin_decode`.
fn prefill_compute(prep: PrefillPrep) -> PrefillOutcome {
    let PrefillPrep { id, tokens, respond, arrived, generate, hit, model, policy, want_snapshot } =
        prep;
    let result = (|| -> Result<PrefillDone> {
        match hit {
            Some(h) => {
                let warm = h.len;
                let cache_pin = Some(h.node);
                // O(prefix) materialization (KV rows AND the owned decode
                // states) happens HERE, outside the engine lock — the
                // lock-held lookup only cloned Arc handles.
                let kv = h.assemble_kv();
                let states = h.states.as_ref().clone();
                let mut sess = DecodeSession::from_cache(kv, states, warm);
                let mut nll = h.nll;
                let mut last = h.last_logits;
                if tokens.len() > warm {
                    // Boundary entry: cached logits row at warm−1 scores the
                    // first un-cached token.
                    nll.push(nll_entry(&last, tokens[warm]));
                    let suffix_logits = model.resume_decode(&mut sess, &tokens[warm..], &policy);
                    let m = suffix_logits.rows;
                    for r in 0..m.saturating_sub(1) {
                        nll.push(nll_entry(suffix_logits.row(r), tokens[warm + r + 1]));
                    }
                    last = suffix_logits.row(m - 1).to_vec();
                }
                let next_token = argmax_row(&last);
                let snapshot = want_snapshot.then(|| {
                    // The cached rows already live in the tree: snapshot
                    // only the suffix the warm path computed (O(suffix)
                    // clone, matching the warm path's cost contract).
                    (
                        tokens.clone(),
                        PrefixSnapshot {
                            kv_from: warm,
                            kv: sess.export_kv_suffix(warm),
                            states: sess.clone_states(),
                            nll: nll.clone(),
                            last_logits: last.clone(),
                        },
                    )
                });
                Ok(PrefillDone { sess, nll, next_token, snapshot, cache_pin })
            }
            None => {
                let (logits, sess) = model.begin_decode(&tokens, &policy)?;
                let nll = nll_from_logits(&logits, &tokens);
                let last = logits.row(logits.rows - 1);
                let next_token = argmax_row(last);
                let snapshot = want_snapshot.then(|| {
                    (
                        tokens.clone(),
                        PrefixSnapshot {
                            kv_from: 0,
                            kv: sess.export_kv(),
                            states: sess.clone_states(),
                            nll: nll.clone(),
                            last_logits: last.to_vec(),
                        },
                    )
                });
                Ok(PrefillDone { sess, nll, next_token, snapshot, cache_pin: None })
            }
        }
    })();
    PrefillOutcome { id, respond, arrived, generate, result }
}

/// Execute one engine work item (prefill batch or decode round). Prefills
/// hold the engine lock only for their admission and installation phases —
/// the forward runs lock-free between them.
fn execute_gen(item: WorkItem, engine: &Mutex<DecodeEngine>, shared: &Mutex<SharedStats>) {
    match item {
        WorkItem::Prefill(ids) => {
            for id in ids {
                let prep = engine.lock().expect("engine poisoned").prepare_prefill(id);
                let Some(prep) = prep else { continue };
                let outcome = prefill_compute(prep);
                engine.lock().expect("engine poisoned").complete_prefill(outcome, shared);
            }
        }
        WorkItem::Decode(ids) => {
            engine.lock().expect("engine poisoned").run_decode(&ids, shared)
        }
    }
}

fn execute_batch(
    cfg: &ServingConfig,
    registry: &mut ArtifactRegistry,
    batch: Batch,
    responders: Vec<Option<Sender<Response>>>,
    shared: &Mutex<SharedStats>,
    backend: &dyn AttentionBackend,
    engine: Option<&Mutex<DecodeEngine>>,
) {
    let lanes = batch.lanes;
    let rt = match registry.get_or_load(&cfg.variant, lanes) {
        Ok(rt) => rt,
        Err(e) => {
            // No loadable artifact: score on the substrate model if the
            // decode engine carries one, otherwise drop (client observes a
            // disconnected responder).
            match engine {
                Some(engine) => substrate_score(batch, responders, shared, backend, engine),
                None => eprintln!("artifact load failure: {e:#}"),
            }
            return;
        }
    };
    // Pad each request to max_seq with BOS (0); pad empty lanes with zeros.
    let mut tokens: Vec<Vec<u32>> = Vec::with_capacity(lanes);
    let mut lens: Vec<usize> = Vec::with_capacity(lanes);
    for req in &batch.requests {
        let mut row = req.tokens.clone();
        row.truncate(cfg.max_seq);
        lens.push(row.len());
        row.resize(cfg.max_seq, 0);
        tokens.push(row);
    }
    while tokens.len() < lanes {
        tokens.push(vec![0; cfg.max_seq]);
        lens.push(0);
    }
    match rt.execute(&tokens) {
        Ok(out) => {
            let mut stats = shared.lock().expect("stats poisoned");
            stats.batches += 1;
            stats.prefills += 1;
            stats.total_lanes += lanes;
            stats.occupied_lanes += batch.requests.len();
            for (i, req) in batch.requests.iter().enumerate() {
                let valid = lens[i].saturating_sub(1);
                let nll = out.nll[i][..valid].to_vec();
                let lat = req.arrived.elapsed();
                stats.latency.record(lat);
                stats.completed += 1;
                stats.scored_tokens += valid;
                if let Some(tx) = &responders[i] {
                    // Real per-request stats from the backend this server is
                    // configured to serve (start() gates explicit specs
                    // against the artifact variant's family and key budget):
                    // the retention/fallback decision is a pure function of
                    // the context length and the backend config, so plan()
                    // reports what the kernel does for this request's
                    // context (previously hardcoded to cfg.prescore_top_k /
                    // false).
                    let attn = backend.plan(lens[i]);
                    let _ = tx.send(Response {
                        id: req.id,
                        nll,
                        generated: Vec::new(),
                        latency_ms: lat.as_secs_f64() * 1e3,
                        kernel: attn.kernel.to_string(),
                        retained_keys: attn.retained_keys,
                        fallback_used: attn.fallback_used,
                        decode_steps: 0,
                        decode_ms: 0.0,
                    });
                }
            }
        }
        Err(e) => eprintln!("execute failure: {e:#}"),
    }
}

/// Scoring fallback on the pure-Rust substrate (no artifact required): full
/// forward + NLL per request under the engine's policy.
fn substrate_score(
    batch: Batch,
    responders: Vec<Option<Sender<Response>>>,
    shared: &Mutex<SharedStats>,
    backend: &dyn AttentionBackend,
    engine: &Mutex<DecodeEngine>,
) {
    // Clone the immutable model/policy handles out of a brief lock and run
    // the (long) scoring forwards lock-free — substrate scoring can no
    // longer stall decode rounds behind the engine mutex.
    let (model, policy) = {
        let eng = engine.lock().expect("engine poisoned");
        (Arc::clone(&eng.model), Arc::clone(&eng.policy))
    };
    let max_seq = model.cfg.max_seq;
    let mut results: Vec<Vec<f32>> = Vec::with_capacity(batch.requests.len());
    for req in &batch.requests {
        let mut toks = req.tokens.clone();
        toks.truncate(max_seq);
        results.push(if toks.len() < 2 {
            Vec::new()
        } else {
            model.nll_policy(&toks, &policy)
        });
    }
    let mut stats = shared.lock().expect("stats poisoned");
    stats.batches += 1;
    stats.prefills += 1;
    stats.total_lanes += batch.lanes;
    stats.occupied_lanes += batch.requests.len();
    for (i, req) in batch.requests.iter().enumerate() {
        let lat = req.arrived.elapsed();
        stats.latency.record(lat);
        stats.completed += 1;
        stats.scored_tokens += results[i].len();
        if let Some(tx) = &responders[i] {
            let attn = backend.plan(req.tokens.len());
            let _ = tx.send(Response {
                id: req.id,
                nll: results[i].clone(),
                generated: Vec::new(),
                latency_ms: lat.as_secs_f64() * 1e3,
                kernel: attn.kernel.to_string(),
                retained_keys: attn.retained_keys,
                fallback_used: attn.fallback_used,
                decode_steps: 0,
                decode_ms: 0.0,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    // End-to-end server tests (substrate scoring + the decode engine on a
    // random model) live in rust/tests/integration_server.rs; unit coverage
    // for the pieces lives in coordinator::*.

    #[test]
    fn worker_count_respects_config_and_pool() {
        let pinned = ServingConfig { executor_workers: 3, ..Default::default() };
        assert_eq!(worker_count(&pinned), 3);
        let auto = ServingConfig { executor_workers: 0, ..Default::default() };
        let derived = crate::parallel::with_threads(5, || worker_count(&auto));
        assert_eq!(derived, 5);
        let capped = crate::parallel::with_threads(64, || worker_count(&auto));
        assert_eq!(capped, 8);
    }

    #[test]
    fn start_fails_fast_without_artifacts() {
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn start_fails_fast_on_bad_attention_spec() {
        // The spec pre-flight runs before the artifact scan, so a malformed
        // [attention] spec is rejected even without built artifacts.
        let cfg = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            attention_spec: "bogus:kernel".into(),
            ..Default::default()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("unknown attention kernel"));
    }

    #[test]
    fn start_rejects_spec_variant_mismatch() {
        // Response stats come from the configured backend; a spec that does
        // not describe the executing artifact would report stats for a
        // kernel that never ran.
        let base = ServingConfig {
            artifacts_dir: "/nonexistent-artifacts".into(),
            ..Default::default()
        };
        // Wrong family: prescored spec on an exact artifact.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("inconsistent"), "{err:#}");
        // Right family, wrong baked-in budget.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            attention_spec: "prescored:kmeans,top_k=8".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // The gate also covers specs derived from the legacy [prescore]
        // keys — a [prescore] top_k that contradicts the variant is the
        // same misreporting bug.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            prescore_top_k: 128,
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("bakes in k=64"), "{err:#}");
        // Unservable kernel: hyper has no artifact family at all.
        let cfg = ServingConfig {
            variant: "exact".into(),
            attention_spec: "hyper:block=32".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("no serving artifact"), "{err:#}");
        // Streaming pre-scoring is substrate-only: the prescored artifacts
        // bake in the full re-cluster.
        let cfg = ServingConfig {
            variant: "prescored_k64".into(),
            attention_spec: "prescored:kmeans,top_k=64,mode=stream".into(),
            ..base.clone()
        };
        let err = ScoringServer::start(cfg).err().expect("must fail");
        assert!(format!("{err:#}").contains("mode=stream"), "{err:#}");
        // Consistent spec/variant pairs pass the gate (and fail later on
        // the missing artifacts instead).
        for (variant, spec) in
            [("prescored_k64", "prescored:kmeans,top_k=64"), ("exact", "flash")]
        {
            let cfg = ServingConfig {
                variant: variant.into(),
                attention_spec: spec.into(),
                ..base.clone()
            };
            let err = ScoringServer::start(cfg).err().expect("must fail");
            assert!(format!("{err:#}").contains("make artifacts"), "{variant}/{spec}: {err:#}");
        }
    }
}
