//! Degrade-don't-reject load shedding.
//!
//! Under pressure (KV page-pool occupancy or prefill queue depth above the
//! configured watermarks) admission steps new requests down a *degradation
//! ladder* instead of rejecting them: each rung trades a little quality or
//! length for throughput, in order of increasing severity —
//!
//! 1. the configured spec (no degradation);
//! 2. a degraded key budget ([`crate::prescore::KeyBudget::degrade`]: half
//!    the fixed `top_k` floored at `shed_min_top_k`, or the attention-mass
//!    target stepped down) — fewer retained keys per step;
//! 3. double the decode refresh period — staler selections, fewer
//!    Algorithm-1 re-runs;
//! 4. `l2norm` scoring — the cheapest pre-scorer (no clustering at all;
//!    still streaming-foldable, so `mode=stream` specs stay valid);
//! 5. half the decode token budget — shorter answers, bounded pool hold.
//!
//! Degradation is *truthful*: the response carries `degraded: true` and the
//! spec string that actually served it. Hysteresis (low watermarks strictly
//! below the high ones) keeps the ladder from oscillating, and once load
//! drains the server walks back to the configured spec without a restart.
//! Non-prescored specs have no quality knobs to turn, so their ladder is
//! just [full, half decode budget].

use crate::attention::{AttentionSpec, AttnPolicy};
use crate::prescore::Method;
use std::sync::Arc;

/// One rung of the degradation ladder: a fully-built serving configuration
/// the admission path can swap in per request.
pub struct Rung {
    pub spec: AttentionSpec,
    /// Canonical spec string, reported in `Response::spec`.
    pub spec_str: String,
    /// Built policy (uniform over layers, like the server's base policy).
    pub policy: Arc<AttnPolicy>,
    /// Decode token budget under this rung.
    pub max_new: usize,
    /// Selection refresh period under this rung (0 = never).
    pub refresh_every: usize,
}

fn rung(spec: AttentionSpec, max_new: usize, fallback_refresh: usize) -> Rung {
    // PreScored rungs own their refresh period (the ladder doubles it);
    // every other family inherits the engine's resolved period — including
    // `restricted:`, whose default-refresh specs defer to the legacy
    // `[prescore] refresh_every` key (see DecodeEngine::new).
    let refresh_every = match &spec {
        AttentionSpec::PreScored(cfg) => cfg.decode_refresh_every,
        _ => fallback_refresh,
    };
    let spec_str = spec.to_string();
    let policy = Arc::new(AttnPolicy::uniform(spec.clone()));
    Rung { spec, spec_str, policy, max_new, refresh_every }
}

/// Build the ladder for `base`. Rung 0 is always the configured spec at
/// full budget; consecutive rungs that change nothing are dropped.
pub fn build_ladder(
    base: &AttentionSpec,
    base_max_new: usize,
    base_refresh: usize,
    min_top_k: usize,
) -> Vec<Rung> {
    let mut ladder = vec![rung(base.clone(), base_max_new, base_refresh)];
    let mut push = |ladder: &mut Vec<Rung>, r: Rung| {
        let last = ladder.last().expect("ladder starts non-empty"); // unwrap-ok: rung 0 above
        if last.spec != r.spec || last.max_new != r.max_new {
            ladder.push(r);
        }
    };
    if let AttentionSpec::PreScored(base_cfg) = base {
        let mut cfg = base_cfg.clone();
        cfg.prescore.budget = cfg.prescore.budget.degrade(min_top_k);
        push(&mut ladder, rung(AttentionSpec::PreScored(cfg.clone()), base_max_new, base_refresh));
        if cfg.decode_refresh_every != 0 {
            cfg.decode_refresh_every *= 2;
        }
        push(&mut ladder, rung(AttentionSpec::PreScored(cfg.clone()), base_max_new, base_refresh));
        // l2norm needs no clustering and is streaming-foldable, so the
        // swap is legal for both full and stream modes.
        cfg.prescore.method = Method::L2Norm;
        push(&mut ladder, rung(AttentionSpec::PreScored(cfg.clone()), base_max_new, base_refresh));
        let short = (base_max_new / 2).max(1);
        push(&mut ladder, rung(AttentionSpec::PreScored(cfg), short, base_refresh));
    } else {
        let short = (base_max_new / 2).max(1);
        push(&mut ladder, rung(base.clone(), short, base_refresh));
    }
    ladder
}

/// Watermark-driven ladder position with hysteresis: one step down the
/// ladder per pressured observation, one step back up per observation with
/// slack. `pin` (the `shed_pin_rung` testing hook) freezes the level.
pub struct LoadShedder {
    high_occ: f64,
    low_occ: f64,
    high_queue: usize,
    low_queue: usize,
    max_level: usize,
    pin: Option<usize>,
    level: usize,
}

impl LoadShedder {
    pub fn new(
        high_occ: f64,
        low_occ: f64,
        high_queue: usize,
        low_queue: usize,
        max_level: usize,
        pin: Option<usize>,
    ) -> LoadShedder {
        LoadShedder { high_occ, low_occ, high_queue, low_queue, max_level, pin, level: 0 }
    }

    /// Fold one admission-time observation (KV pool occupancy in [0, 1],
    /// pending prefill depth) and return the rung to serve at.
    pub fn observe(&mut self, occupancy: f64, queue_depth: usize) -> usize {
        if let Some(p) = self.pin {
            self.level = p.min(self.max_level);
            return self.level;
        }
        if occupancy >= self.high_occ || queue_depth >= self.high_queue {
            self.level = (self.level + 1).min(self.max_level);
        } else if occupancy <= self.low_occ && queue_depth <= self.low_queue {
            self.level = self.level.saturating_sub(1);
        }
        // Between the watermarks: hold position (hysteresis band).
        self.level
    }

    pub fn level(&self) -> usize {
        self.level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_rungs_roundtrip_the_spec_grammar() {
        for base in [
            "prescored:kmeans,top_k=64,block=16,sample=8",
            "prescored:kmeans,top_k=64,delta=0.05,mode=stream",
            "prescored:minibatch,top_k=32,refresh=8",
            "exact",
            "flash:block_q=16",
        ] {
            let spec = AttentionSpec::parse(base).unwrap();
            let ladder = build_ladder(&spec, 64, 16, 8);
            assert_eq!(ladder[0].spec, spec, "rung 0 is the configured spec");
            assert_eq!(ladder[0].max_new, 64);
            for r in &ladder {
                let reparsed = AttentionSpec::parse(&r.spec_str)
                    .unwrap_or_else(|e| panic!("rung '{}' of {base}: {e}", r.spec_str));
                assert_eq!(reparsed, r.spec, "canonical form roundtrips");
                assert!(r.max_new >= 1);
            }
        }
    }

    #[test]
    fn prescored_ladder_degrades_monotonically() {
        let spec = AttentionSpec::parse("prescored:kmeans,top_k=64,mode=stream").unwrap();
        let ladder = build_ladder(&spec, 64, 16, 8);
        assert!(ladder.len() >= 4, "prescored specs get a real ladder");
        let top_k = |r: &Rung| match &r.spec {
            AttentionSpec::PreScored(c) => {
                c.prescore.budget.fixed_k().expect("fixed-budget ladder") // unwrap-ok: test spec
            }
            _ => unreachable!(),
        };
        for w in ladder.windows(2) {
            assert!(top_k(&w[1]) <= top_k(&w[0]), "top_k never grows down-ladder");
            assert!(w[1].max_new <= w[0].max_new);
        }
        assert!(top_k(ladder.last().unwrap()) >= 8, "min_top_k floor holds");
        let last = ladder.last().unwrap();
        match &last.spec {
            AttentionSpec::PreScored(c) => {
                assert_eq!(c.prescore.method, Method::L2Norm);
                assert!(c.mode == crate::attention::PreScoreMode::Stream, "mode preserved");
            }
            other => panic!("ladder changed kernel family: {other:?}"),
        }
        assert_eq!(last.max_new, 32);
        // Already-minimal specs collapse to a short ladder, not a panic.
        let tiny = AttentionSpec::parse("prescored:l2norm,top_k=8,refresh=0").unwrap();
        let l = build_ladder(&tiny, 1, 0, 8);
        assert!(!l.is_empty());
        for r in &l {
            assert_eq!(r.max_new, 1);
            assert_eq!(r.refresh_every, 0, "refresh=never stays never");
        }
    }

    #[test]
    fn mass_budget_ladder_steps_target_down() {
        use crate::prescore::KeyBudget;
        let spec = AttentionSpec::parse("prescored:kmeans,mass=0.9,mode=stream").unwrap();
        let ladder = build_ladder(&spec, 64, 16, 8);
        assert!(ladder.len() >= 4, "mass specs get the full ladder");
        let mass = |r: &Rung| match &r.spec {
            AttentionSpec::PreScored(c) => match c.prescore.budget {
                KeyBudget::Mass(p) => p,
                other => panic!("ladder switched budget form: {other:?}"),
            },
            _ => unreachable!(),
        };
        for w in ladder.windows(2) {
            assert!(mass(&w[1]) <= mass(&w[0]), "mass target never grows down-ladder");
        }
        assert!(mass(ladder.last().unwrap()) >= KeyBudget::MASS_DEGRADE_MIN);
        // Truthful reporting: every rung's spec string round-trips the
        // grammar, so a degraded mass target is observable over the wire.
        for r in &ladder {
            assert_eq!(AttentionSpec::parse(&r.spec_str).unwrap(), r.spec, "{}", r.spec_str);
        }
    }

    #[test]
    fn non_prescored_ladder_only_shortens() {
        let ladder = build_ladder(&AttentionSpec::Exact, 64, 16, 8);
        assert_eq!(ladder.len(), 2);
        assert_eq!(ladder[0].spec_str, "exact");
        assert_eq!(ladder[1].spec_str, "exact");
        assert_eq!(ladder[1].max_new, 32);
        assert_eq!(ladder[1].refresh_every, 16, "fallback refresh threads through");
    }

    #[test]
    fn shedder_hysteresis() {
        let mut s = LoadShedder::new(0.85, 0.5, 8, 1, 4, None);
        assert_eq!(s.observe(0.2, 0), 0, "idle holds rung 0");
        assert_eq!(s.observe(0.9, 0), 1, "occupancy pressure steps down");
        assert_eq!(s.observe(0.2, 9), 2, "queue pressure steps down");
        assert_eq!(s.observe(0.7, 4), 2, "between watermarks holds (hysteresis)");
        assert_eq!(s.observe(0.95, 20), 3);
        assert_eq!(s.observe(0.95, 20), 4);
        assert_eq!(s.observe(0.95, 20), 4, "clamped at the last rung");
        assert_eq!(s.observe(0.3, 0), 3, "slack steps back up");
        for _ in 0..10 {
            s.observe(0.1, 0);
        }
        assert_eq!(s.level(), 0, "full recovery without restart");
    }

    #[test]
    fn shedder_pin_overrides_load() {
        let mut s = LoadShedder::new(0.85, 0.5, 8, 1, 4, Some(2));
        assert_eq!(s.observe(0.0, 0), 2);
        assert_eq!(s.observe(1.0, 100), 2);
        let mut over = LoadShedder::new(0.85, 0.5, 8, 1, 1, Some(9));
        assert_eq!(over.observe(0.0, 0), 1, "pin clamps to the ladder length");
    }
}
