//! Cooperative cancellation for in-flight requests.
//!
//! A [`CancelToken`] is a shared flag checked at the serving stack's safe
//! points — prefill-prepare, between decode rounds, inside the decode step
//! loop — never mid-kernel, so a cancelled request's teardown always sees a
//! consistent KV/pin state. The [`CancelRegistry`] maps request ids to
//! tokens: `ScoringServer::submit` registers, `ScoringServer::cancel` trips
//! the flag from any thread, and the engine removes the entry when the
//! request reaches a terminal state (cancelling a finished request is a
//! no-op that returns `false`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// A shared cancellation flag. Cloning shares the flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the flag. Idempotent.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Request-id → token map shared between the client handle and the serving
/// threads.
#[derive(Default)]
pub struct CancelRegistry {
    tokens: Mutex<HashMap<u64, CancelToken>>,
}

impl CancelRegistry {
    pub fn new() -> CancelRegistry {
        CancelRegistry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, CancelToken>> {
        // A panicking holder leaves the map fully usable (single-item ops).
        self.tokens.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// The token for `id`, creating one if the request is new.
    pub fn register(&self, id: u64) -> CancelToken {
        self.lock().entry(id).or_default().clone()
    }

    /// The token for `id`, if the request is still live.
    pub fn get(&self, id: u64) -> Option<CancelToken> {
        self.lock().get(&id).cloned()
    }

    /// Trip `id`'s token. Returns `false` when the request is unknown or
    /// already finished — cancellation of a completed request is a no-op.
    pub fn cancel(&self, id: u64) -> bool {
        match self.lock().get(&id) {
            Some(t) => {
                t.cancel();
                true
            }
            None => false,
        }
    }

    /// Drop `id`'s entry (terminal state reached).
    pub fn remove(&self, id: u64) {
        self.lock().remove(&id);
    }

    /// Number of live (registered, not yet terminal) requests.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_trips_once_and_shares_state() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let u = t.clone();
        u.cancel();
        assert!(t.is_cancelled(), "clones share the flag");
        t.cancel();
        assert!(t.is_cancelled(), "idempotent");
    }

    #[test]
    fn registry_lifecycle() {
        let reg = CancelRegistry::new();
        assert!(!reg.cancel(7), "cancelling an unknown id is a no-op");
        let t = reg.register(7);
        assert_eq!(reg.len(), 1);
        assert!(reg.cancel(7));
        assert!(t.is_cancelled(), "registry cancel reaches the held token");
        assert!(reg.get(7).is_some());
        reg.remove(7);
        assert!(reg.get(7).is_none());
        assert!(!reg.cancel(7), "post-completion cancel reports false");
        assert!(reg.is_empty());
    }

    #[test]
    fn register_is_stable_across_calls() {
        let reg = CancelRegistry::new();
        let a = reg.register(3);
        let b = reg.register(3);
        b.cancel();
        assert!(a.is_cancelled(), "same id → same underlying token");
    }
}
