//! Per-session lifecycle for resumable SSE streams.
//!
//! Every streaming request admitted through the gateway gets a
//! [`SessionHub`] entry: a server-issued session id, a bounded replay
//! buffer of emitted tokens (sequence-numbered from 1), and an attachment
//! state tracking whether a client is currently connected. The hub is the
//! single routing point between the decode engine (which emits tokens by
//! engine request id) and the wire (which addresses sessions by the opaque
//! session id a client echoes back in `Last-Event-ID`).
//!
//! Lifecycle: [`SessionHub::open`] (admitted, client attached) →
//! [`SessionHub::park`] (client vanished: decode pauses, KV pages stay
//! pinned, the entry lingers for `session_linger_ms`) → either
//! [`SessionHub::attach_for_resume`] (client reconnected: replay the
//! buffered suffix, continue decoding) or expiry
//! ([`SessionHub::take_expired`] feeds the engine's cancel path, which
//! reclaims pages/pins with balanced accounting). [`SessionHub::finish`]
//! records the terminal exactly once; a late resume of a finished session
//! replays the buffered tail plus the stored terminal without touching the
//! engine. Across a restart, [`SessionHub::records`] /
//! [`SessionHub::restore`] round-trip unfinished detached sessions through
//! the versioned `cache::persist` store; restored entries are not
//! engine-bound, so a resume re-admits the context (warm via the persisted
//! prefix cache — no second cold prefill) and fast-forwards: [`SessionHub::emit`]
//! suppresses regenerated sequence numbers at or below the high-water
//! mark, which greedy decode makes bitwise identical to the original
//! stream.
//!
//! Lock order: the engine mutex may be held while calling into the hub;
//! hub methods never call back into the engine.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::StreamEvent;
use crate::cache::persist::SessionRecord;
use crate::coordinator::Response;
use crate::fault::{self, FaultPoint};

/// Where a session's client currently is.
enum Attach {
    /// A client is connected: tokens forward live, the terminal goes out on
    /// `terminal` exactly once.
    Attached { events: Sender<StreamEvent>, terminal: Sender<Response> },
    /// The client vanished mid-stream; decode is paused and the entry
    /// expires `linger` after `since` unless a resume re-attaches.
    Parked { since: Instant },
    /// No client and no engine work pending (finished, persisted, or
    /// restored from a store). Resumable until the linger GC collects it.
    Detached { since: Instant },
}

struct SessionEntry {
    /// Engine request id currently producing for this session. Stale (and
    /// `engine_bound == false`) for entries restored from a persisted store.
    request_id: u64,
    /// Whether `request_id` names a live registration in *this* process's
    /// engine. Restored entries are unbound: resume must re-admit.
    engine_bound: bool,
    tenant: String,
    /// Full request context — kept so an unbound resume can re-admit.
    context: Vec<u32>,
    /// Total tokens the original request asked to generate.
    target: usize,
    /// Replay window: the most recent emitted tokens, oldest first.
    emitted: VecDeque<u32>,
    /// Sequence number (1-based) of `emitted.front()`.
    base: usize,
    /// High-water sequence number: count of tokens ever emitted.
    total: usize,
    /// Terminal response, recorded exactly once by `finish`.
    finished: Option<Response>,
    attach: Attach,
}

/// Why a resume attempt was refused (the gateway maps these to HTTP
/// statuses: Unknown → 404, ReplayLost → 410, Busy → 409, BadCursor → 400).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResumeError {
    /// No such session id (never existed, expired, or GC'd).
    Unknown,
    /// Another client is still attached to this session.
    Busy,
    /// The cursor is ahead of anything the server ever emitted.
    BadCursor { high_water: usize },
    /// The replay buffer no longer reaches back to the cursor: the oldest
    /// buffered sequence number is `window_start`.
    ReplayLost { window_start: usize },
}

impl std::fmt::Display for ResumeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResumeError::Unknown => write!(f, "unknown session"),
            ResumeError::Busy => write!(f, "session already attached"),
            ResumeError::BadCursor { high_water } => {
                write!(f, "cursor past high water {high_water}")
            }
            ResumeError::ReplayLost { window_start } => {
                write!(f, "replay window starts at {window_start}")
            }
        }
    }
}

/// What a successful [`SessionHub::attach_for_resume`] hands back: the
/// buffered `(seq, token)` suffix to replay, plus what the server layer
/// needs to wake (engine-bound) or re-admit (restored) the session.
pub struct Resumption {
    pub request_id: u64,
    pub engine_bound: bool,
    pub tenant: String,
    pub context: Vec<u32>,
    pub target: usize,
    /// Buffered tokens with sequence numbers strictly after the cursor.
    pub replay: Vec<(usize, u32)>,
    /// Present when the session already finished: the stored terminal.
    /// No channels were installed; the caller replays and closes.
    pub done: Option<Response>,
}

/// Session counters for `ServerStats` / the gateway stats endpoint.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionCounters {
    /// Entries currently held (attached + parked + detached-but-resumable).
    pub live: usize,
    /// Cumulative attached → parked transitions.
    pub parked: u64,
    /// Cumulative successful re-attaches.
    pub resumed: u64,
    /// Cumulative parked entries reclaimed by linger expiry (or the
    /// `session_expire` fault point).
    pub expired: u64,
    /// Cumulative entries detached for persistence at drain.
    pub persisted: u64,
    /// Cumulative entries restored from a persisted store.
    pub recovered: u64,
}

struct HubInner {
    by_sid: HashMap<String, SessionEntry>,
    /// Engine request id → session id, for `emit`/`finish` routing. Only
    /// engine-bound entries appear here.
    by_req: HashMap<u64, String>,
    next: u64,
    parked: u64,
    resumed: u64,
    expired: u64,
    persisted: u64,
    recovered: u64,
}

/// The session registry shared by the engine, the run loop, and the
/// gateway-facing `ScoringServer` session API.
pub struct SessionHub {
    inner: Mutex<HubInner>,
    /// Process-unique prefix for session ids, so ids from a previous
    /// incarnation can't collide with (or be confused for) this one's.
    boot: u64,
    linger: Duration,
    replay_cap: usize,
}

impl SessionHub {
    pub fn new(linger_ms: u64, replay_tokens: usize) -> SessionHub {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let boot = crate::fault::splitmix64(nanos ^ (u64::from(std::process::id()) << 32));
        SessionHub {
            inner: Mutex::new(HubInner {
                by_sid: HashMap::new(),
                by_req: HashMap::new(),
                next: 0,
                parked: 0,
                resumed: 0,
                expired: 0,
                persisted: 0,
                recovered: 0,
            }),
            boot,
            linger: Duration::from_millis(linger_ms),
            replay_cap: replay_tokens.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HubInner> {
        // Hub ops are single-entry map edits; a panicking holder leaves the
        // maps usable.
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Register a new streaming session and return its server-issued id.
    pub fn open(
        &self,
        request_id: u64,
        tenant: &str,
        context: Vec<u32>,
        target: usize,
        events: Sender<StreamEvent>,
        terminal: Sender<Response>,
    ) -> String {
        let mut g = self.lock();
        g.next += 1;
        let sid = format!("{:016x}-{:x}", self.boot, g.next);
        g.by_req.insert(request_id, sid.clone());
        g.by_sid.insert(
            sid.clone(),
            SessionEntry {
                request_id,
                engine_bound: true,
                tenant: tenant.to_string(),
                context,
                target,
                emitted: VecDeque::new(),
                base: 1,
                total: 0,
                finished: None,
                attach: Attach::Attached { events, terminal },
            },
        );
        sid
    }

    /// Record one emitted token for `request_id` at sequence number `seq`
    /// (1-based) and forward it to the attached client, if any. Sequence
    /// numbers at or below the high-water mark are suppressed — that is the
    /// fast-forward path when a restored session regenerates its prefix.
    /// Returns whether the request id routes to a session.
    pub fn emit(&self, request_id: u64, seq: usize, token: u32) -> bool {
        let mut g = self.lock();
        let Some(sid) = g.by_req.get(&request_id).cloned() else {
            return false;
        };
        let Some(e) = g.by_sid.get_mut(&sid) else {
            return false;
        };
        if seq <= e.total {
            // Regenerated position (greedy decode replays deterministically);
            // the client already has it — from the live stream or the buffer.
            return true;
        }
        e.total = seq;
        e.emitted.push_back(token);
        // The overflow fault shrinks the window to one token so chaos runs
        // exercise the ReplayLost refusal without a 512-token stream.
        let cap = if fault::fires(FaultPoint::ReplayOverflow, request_id) {
            1
        } else {
            self.replay_cap
        };
        while e.emitted.len() > cap {
            e.emitted.pop_front();
            e.base += 1;
        }
        if let Attach::Attached { events, .. } = &e.attach {
            // A dead receiver is handled by the gateway's disconnect path
            // (park), not here — emit never mutates attachment.
            let _ = events.send(StreamEvent {
                id: request_id,
                tokens: vec![token],
                total: seq,
            });
        }
        true
    }

    /// Record `request_id`'s terminal. Sends it to the attached client (if
    /// any), stores it for late resumes, detaches, and unbinds the request
    /// id. Returns `false` when the id routes to no session — the caller
    /// owns terminal delivery in that case.
    pub fn finish(&self, request_id: u64, resp: &Response) -> bool {
        let mut g = self.lock();
        let Some(sid) = g.by_req.remove(&request_id) else {
            return false;
        };
        let Some(e) = g.by_sid.get_mut(&sid) else {
            return false;
        };
        if let Attach::Attached { terminal, .. } = &e.attach {
            let _ = terminal.send(resp.clone());
        }
        e.finished = Some(resp.clone());
        e.engine_bound = false;
        // Dropping the senders disconnects the event channel — that is how
        // an attached gateway loop learns the stream is over.
        e.attach = Attach::Detached { since: Instant::now() };
        true
    }

    /// The client vanished: park the session (decode pauses at the next
    /// safe point; the entry lingers, resumable). Returns the engine
    /// request id, or `None` when the session is unknown or already
    /// finished (nothing to park).
    pub fn park(&self, sid: &str) -> Option<u64> {
        let mut g = self.lock();
        let e = g.by_sid.get_mut(sid)?;
        if e.finished.is_some() {
            return None;
        }
        if matches!(e.attach, Attach::Attached { .. }) {
            e.attach = Attach::Parked { since: Instant::now() };
            g.parked += 1;
        }
        g.by_sid.get(sid).map(|e| e.request_id)
    }

    /// Whether the engine should pause decoding `request_id` (its session
    /// is parked). Safe to call lock-free relative to the engine.
    pub fn park_requested(&self, request_id: u64) -> bool {
        let g = self.lock();
        g.by_req
            .get(&request_id)
            .and_then(|sid| g.by_sid.get(sid))
            .is_some_and(|e| matches!(e.attach, Attach::Parked { .. }))
    }

    /// Re-attach a client at cursor `after` (= last sequence number it
    /// received; 0 = from the start). On success the buffered suffix comes
    /// back for replay and — unless the session already finished — the
    /// channels are installed for live continuation.
    pub fn attach_for_resume(
        &self,
        sid: &str,
        after: usize,
        events: Sender<StreamEvent>,
        terminal: Sender<Response>,
    ) -> Result<Resumption, ResumeError> {
        let mut g = self.lock();
        let Some(e) = g.by_sid.get_mut(sid) else {
            return Err(ResumeError::Unknown);
        };
        if matches!(e.attach, Attach::Attached { .. }) {
            return Err(ResumeError::Busy);
        }
        if after > e.total {
            return Err(ResumeError::BadCursor { high_water: e.total });
        }
        if after + 1 < e.base {
            return Err(ResumeError::ReplayLost { window_start: e.base });
        }
        let skip = after + 1 - e.base;
        let base = e.base;
        let replay: Vec<(usize, u32)> =
            e.emitted.iter().enumerate().skip(skip).map(|(i, &t)| (base + i, t)).collect();
        let done = e.finished.clone();
        if done.is_none() {
            e.attach = Attach::Attached { events, terminal };
        }
        let out = Resumption {
            request_id: e.request_id,
            engine_bound: e.engine_bound,
            tenant: e.tenant.clone(),
            context: e.context.clone(),
            target: e.target,
            replay,
            done,
        };
        if out.done.is_none() {
            g.resumed += 1;
        }
        Ok(out)
    }

    /// Rebind a session to a fresh engine request id (the re-admit path for
    /// restored sessions). The new id routes `emit`/`finish` from now on.
    pub fn rekey(&self, sid: &str, new_id: u64) {
        let mut g = self.lock();
        let Some(e) = g.by_sid.get_mut(sid) else {
            return;
        };
        let old = e.request_id;
        e.request_id = new_id;
        e.engine_bound = true;
        g.by_req.remove(&old);
        g.by_req.insert(new_id, sid.to_string());
    }

    /// Detach a parked session ahead of drain persistence: unbind the
    /// request id so the engine's subsequent teardown terminal does NOT
    /// finish the entry — it survives as a clean resumable record for
    /// [`SessionHub::records`]. Returns whether the id routed to a session.
    pub fn detach_for_persist(&self, request_id: u64) -> bool {
        let mut g = self.lock();
        let Some(sid) = g.by_req.remove(&request_id) else {
            return false;
        };
        let Some(e) = g.by_sid.get_mut(&sid) else {
            return false;
        };
        e.engine_bound = false;
        e.attach = Attach::Detached { since: Instant::now() };
        g.persisted += 1;
        true
    }

    /// Collect expired sessions: parked entries past the linger window (or
    /// force-expired by the `session_expire` fault point) are removed and
    /// their engine request ids returned so the caller can run the cancel
    /// path; detached entries past the linger window are GC'd in place.
    pub fn take_expired(&self) -> Vec<u64> {
        let mut g = self.lock();
        let linger = self.linger;
        let mut reclaim = Vec::new();
        let mut drop_sids = Vec::new();
        for (sid, e) in &g.by_sid {
            match e.attach {
                Attach::Parked { since } => {
                    if since.elapsed() >= linger
                        || fault::fires(FaultPoint::SessionExpire, e.request_id)
                    {
                        reclaim.push(e.request_id);
                        drop_sids.push(sid.clone());
                    }
                }
                Attach::Detached { since } => {
                    if since.elapsed() >= linger {
                        drop_sids.push(sid.clone());
                    }
                }
                Attach::Attached { .. } => {}
            }
        }
        g.expired += reclaim.len() as u64;
        for sid in drop_sids {
            if let Some(e) = g.by_sid.remove(&sid) {
                g.by_req.remove(&e.request_id);
            }
        }
        reclaim
    }

    /// Unfinished, detached sessions as persistable records (sorted by id
    /// for a deterministic store).
    pub fn records(&self) -> Vec<SessionRecord> {
        let g = self.lock();
        let mut out: Vec<SessionRecord> = g
            .by_sid
            .iter()
            .filter(|(_, e)| e.finished.is_none() && matches!(e.attach, Attach::Detached { .. }))
            .map(|(sid, e)| SessionRecord {
                sid: sid.clone(),
                tenant: e.tenant.clone(),
                context: e.context.clone(),
                target: e.target as u32,
                base: e.base as u32,
                total: e.total as u32,
                emitted: e.emitted.iter().copied().collect(),
            })
            .collect();
        out.sort_by(|a, b| a.sid.cmp(&b.sid));
        out
    }

    /// Re-register sessions from a persisted store. Restored entries are
    /// detached and NOT engine-bound — a resume re-admits their context
    /// (warm through the restored prefix cache) and fast-forwards.
    pub fn restore(&self, records: Vec<SessionRecord>) {
        let mut g = self.lock();
        for r in records {
            g.recovered += 1;
            g.by_sid.insert(
                r.sid,
                SessionEntry {
                    request_id: 0,
                    engine_bound: false,
                    tenant: r.tenant,
                    context: r.context,
                    target: r.target as usize,
                    emitted: r.emitted.into_iter().collect(),
                    base: r.base as usize,
                    total: r.total as usize,
                    finished: None,
                    attach: Attach::Detached { since: Instant::now() },
                },
            );
        }
    }

    pub fn counters(&self) -> SessionCounters {
        let g = self.lock();
        SessionCounters {
            live: g.by_sid.len(),
            parked: g.parked,
            resumed: g.resumed,
            expired: g.expired,
            persisted: g.persisted,
            recovered: g.recovered,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerError;
    use std::sync::mpsc::channel;

    fn resp(id: u64) -> Response {
        Response::failure(id, 0.0, "test".into(), ServerError::Cancelled)
    }

    fn hub(linger_ms: u64, cap: usize) -> SessionHub {
        SessionHub::new(linger_ms, cap)
    }

    #[test]
    fn open_emit_forward_and_buffer() {
        let h = hub(10_000, 8);
        let (etx, erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(7, "t", vec![1, 2], 4, etx, ttx);
        assert!(h.emit(7, 1, 10));
        assert!(h.emit(7, 2, 11));
        let ev = erx.recv().unwrap();
        assert_eq!((ev.id, ev.total, ev.tokens.clone()), (7, 1, vec![10]));
        assert_eq!(erx.recv().unwrap().total, 2);
        assert!(!h.emit(99, 1, 0), "unknown id routes nowhere");
        assert_eq!(h.counters().live, 1);
        assert!(!sid.is_empty());
    }

    #[test]
    fn replay_window_trims_and_reports_loss() {
        let h = hub(10_000, 2);
        let (etx, _erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(1, "t", vec![], 8, etx, ttx);
        for (seq, tok) in [(1usize, 100u32), (2, 101), (3, 102), (4, 103)] {
            h.emit(1, seq, tok);
        }
        assert_eq!(h.park(&sid), Some(1));
        // Window now holds seqs 3..=4; cursor 1 is unreachable.
        let (e2, _r2) = channel();
        let (t2, _u2) = channel();
        match h.attach_for_resume(&sid, 1, e2, t2) {
            Err(ResumeError::ReplayLost { window_start }) => assert_eq!(window_start, 3),
            other => panic!("expected ReplayLost, got {:?}", other.err()),
        }
        let (e3, _r3) = channel();
        let (t3, _u3) = channel();
        let out = h.attach_for_resume(&sid, 2, e3, t3).expect("cursor 2 is in-window");
        assert_eq!(out.replay, vec![(3, 102), (4, 103)]);
    }

    #[test]
    fn suppression_fast_forwards_below_high_water() {
        let h = hub(10_000, 8);
        let (etx, erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(5, "t", vec![], 8, etx, ttx);
        h.emit(5, 1, 10);
        h.emit(5, 2, 11);
        assert_eq!(h.park(&sid), Some(5));
        let (e2, r2) = channel();
        let (t2, _u2) = channel();
        let out = h.attach_for_resume(&sid, 2, e2, t2).expect("resume");
        assert!(out.replay.is_empty(), "cursor at high water → nothing to replay");
        // A restored-style regeneration replays seqs 1..=2 — suppressed —
        // then continues with fresh ones.
        h.rekey(&sid, 50);
        assert!(h.emit(50, 1, 10));
        assert!(h.emit(50, 2, 11));
        assert!(h.emit(50, 3, 12));
        let ev = r2.recv().unwrap();
        assert_eq!((ev.total, ev.tokens.clone()), (3, vec![12]), "only the fresh token lands");
    }

    #[test]
    fn finish_is_exactly_once_and_survives_for_late_resume() {
        let h = hub(10_000, 8);
        let (etx, _erx) = channel();
        let (ttx, trx) = channel();
        let sid = h.open(3, "t", vec![], 2, etx, ttx);
        h.emit(3, 1, 42);
        assert!(h.finish(3, &resp(3)));
        assert!(trx.recv().is_ok(), "attached client gets the terminal");
        assert!(!h.finish(3, &resp(3)), "request id is unbound after finish");
        assert_eq!(h.park(&sid), None, "finished sessions don't park");
        let (e2, _r2) = channel();
        let (t2, u2) = channel();
        let out = h.attach_for_resume(&sid, 0, e2, t2).expect("late resume");
        assert_eq!(out.replay, vec![(1, 42)]);
        assert!(out.done.is_some(), "stored terminal rides along");
        drop(u2);
    }

    #[test]
    fn park_expire_reclaims_and_forgets() {
        let h = hub(0, 8);
        let (etx, _erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(9, "t", vec![], 4, etx, ttx);
        assert!(h.take_expired().is_empty(), "attached sessions never expire");
        assert_eq!(h.park(&sid), Some(9));
        let reclaimed = h.take_expired();
        assert_eq!(reclaimed, vec![9]);
        let (e2, _r2) = channel();
        let (t2, _u2) = channel();
        assert!(matches!(
            h.attach_for_resume(&sid, 0, e2, t2),
            Err(ResumeError::Unknown)
        ));
        let c = h.counters();
        assert_eq!((c.live, c.expired), (0, 1));
    }

    #[test]
    fn busy_and_bad_cursor_refusals() {
        let h = hub(10_000, 8);
        let (etx, _erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(2, "t", vec![], 4, etx, ttx);
        h.emit(2, 1, 7);
        let (e2, _r2) = channel();
        let (t2, _u2) = channel();
        assert!(matches!(h.attach_for_resume(&sid, 0, e2, t2), Err(ResumeError::Busy)));
        h.park(&sid);
        let (e3, _r3) = channel();
        let (t3, _u3) = channel();
        match h.attach_for_resume(&sid, 5, e3, t3) {
            Err(ResumeError::BadCursor { high_water }) => assert_eq!(high_water, 1),
            other => panic!("expected BadCursor, got {:?}", other.err()),
        }
    }

    #[test]
    fn records_restore_roundtrip() {
        let h = hub(10_000, 8);
        let (etx, _erx) = channel();
        let (ttx, _trx) = channel();
        let sid = h.open(4, "acme", vec![1, 2, 3], 6, etx, ttx);
        h.emit(4, 1, 20);
        h.emit(4, 2, 21);
        h.park(&sid);
        assert!(h.records().is_empty(), "parked-but-bound entries are not persisted");
        assert!(h.detach_for_persist(4));
        let recs = h.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].sid, sid);
        assert_eq!(recs[0].emitted, vec![20, 21]);
        assert_eq!((recs[0].base, recs[0].total, recs[0].target), (1, 2, 6));

        let h2 = hub(10_000, 8);
        h2.restore(recs);
        let (e2, r2) = channel();
        let (t2, _u2) = channel();
        let out = h2.attach_for_resume(&sid, 0, e2, t2).expect("restored resume");
        assert!(!out.engine_bound, "restored sessions must re-admit");
        assert_eq!(out.replay, vec![(1, 20), (2, 21)]);
        assert_eq!(out.context, vec![1, 2, 3]);
        // Re-admit under a fresh id; regeneration fast-forwards.
        h2.rekey(&sid, 77);
        h2.emit(77, 1, 20);
        h2.emit(77, 2, 21);
        h2.emit(77, 3, 22);
        assert_eq!(r2.recv().unwrap().tokens, vec![22]);
        assert_eq!(h2.counters().recovered, 1);
    }
}
