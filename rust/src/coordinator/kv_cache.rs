//! Block-allocated KV-cache manager.
//!
//! Pages of `BLOCK_SIZE` token slots are allocated from a fixed pool with
//! ref-counting (shared prefixes can share pages). The manager also owns the
//! per-(sequence, selection-slot) *key-selection sets* produced by the
//! pre-score manager — the paper's cached prefill selection — so eviction of
//! a sequence releases both its KV pages and its selections atomically.
//!
//! The serving decode engine (`server::DecodeEngine`) drives this manager:
//! `admit` at prefill, `append_token` per decode step (page growth gates
//! token streaming), `set_selections` at every selection refresh, and
//! `evict` at completion. The "layer" count is a *slot* count — the engine
//! uses one slot per layer·head so the cached selections mirror the
//! per-head `DecodeState`s exactly.

use std::collections::HashMap;

pub const BLOCK_SIZE: usize = 16;

/// Pages a run of `tokens` tokens occupies — the single rounding rule shared
/// by the KV manager's admission, the server's capacity pre-check, and the
/// prefix cache's page accounting (divergence between them would let a
/// pre-check pass while the allocation fails, or skew eviction budgets).
pub fn pages_for(tokens: usize) -> usize {
    tokens.div_ceil(BLOCK_SIZE).max(1)
}

/// A page of KV storage (identified by index into the pool).
pub type BlockId = usize;

/// Fixed-pool block allocator with ref counts.
pub struct BlockAllocator {
    refcounts: Vec<u32>,
    free: Vec<BlockId>,
    /// Blocks permanently removed from this pool by `withdraw` (their ids
    /// stay tombstoned so live block ids never dangle).
    withdrawn: usize,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize) -> Self {
        BlockAllocator {
            refcounts: vec![0; num_blocks],
            free: (0..num_blocks).rev().collect(),
            withdrawn: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.refcounts.len() - self.withdrawn
    }

    /// Grow the pool by `n` fresh blocks (budget transferred in from
    /// another pool — see `withdraw`).
    pub fn add_blocks(&mut self, n: usize) {
        for _ in 0..n {
            let id = self.refcounts.len();
            self.refcounts.push(0);
            self.free.push(id);
        }
    }

    /// Permanently remove up to `n` free blocks from this pool, returning
    /// how many were withdrawn. The removed ids are tombstoned (refcount
    /// pinned above zero, never pushed back to the free list) so existing
    /// `BlockId`s remain valid. This is the one-way page-budget transfer
    /// the admission path uses: prefix-cache pages shed under pressure are
    /// withdrawn here and re-added to the KV pool via `add_blocks`.
    pub fn withdraw(&mut self, n: usize) -> usize {
        let take = n.min(self.free.len());
        for _ in 0..take {
            let id = self.free.pop().expect("free list length checked above");
            debug_assert_eq!(self.refcounts[id], 0);
            self.refcounts[id] = u32::MAX; // tombstone: never freed again
        }
        self.withdrawn += take;
        take
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Allocate one block (refcount 1).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcounts[id], 0);
        self.refcounts[id] = 1;
        Some(id)
    }

    /// Increment refcount (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refcounts[id] > 0, "retain of free block {id}");
        self.refcounts[id] += 1;
    }

    /// Decrement refcount; the block returns to the pool at zero.
    pub fn release(&mut self, id: BlockId) {
        assert!(self.refcounts[id] > 0, "double free of block {id}");
        self.refcounts[id] -= 1;
        if self.refcounts[id] == 0 {
            self.free.push(id);
        }
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcounts[id]
    }
}

/// Per-sequence cache state.
struct SeqEntry {
    blocks: Vec<BlockId>,
    tokens: usize,
    /// Cached key selections per layer (indices into the sequence).
    selections: Vec<Vec<usize>>,
    /// Decode steps since the selection was last refreshed.
    steps_since_refresh: usize,
}

/// The KV-cache manager: sequence → pages + cached selections.
pub struct KvCacheManager {
    alloc: BlockAllocator,
    seqs: HashMap<u64, SeqEntry>,
    num_layers: usize,
    /// Lifetime page-accounting: every page handed to a sequence is
    /// counted here, and every page returned by `evict` in
    /// `pages_released`. The serving layer's fault tests assert
    /// acquired == released once all sequences are torn down — the
    /// no-leak invariant that survives cancellations and worker panics.
    pages_acquired: usize,
    pages_released: usize,
}

impl KvCacheManager {
    pub fn new(num_blocks: usize, num_layers: usize) -> Self {
        KvCacheManager {
            alloc: BlockAllocator::new(num_blocks),
            seqs: HashMap::new(),
            num_layers,
            pages_acquired: 0,
            pages_released: 0,
        }
    }

    /// Admit a sequence with `tokens` context tokens; allocates
    /// ceil(tokens/BLOCK_SIZE) pages. Fails (None) if the pool is exhausted,
    /// leaving no partial allocation behind.
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> Option<()> {
        assert!(!self.seqs.contains_key(&seq_id), "sequence {seq_id} already admitted");
        let need = pages_for(tokens);
        if self.alloc.free_blocks() < need {
            return None;
        }
        let blocks: Vec<BlockId> = (0..need).map(|_| self.alloc.alloc().unwrap()).collect();
        self.pages_acquired += need;
        self.seqs.insert(
            seq_id,
            SeqEntry {
                blocks,
                tokens,
                selections: vec![Vec::new(); self.num_layers],
                steps_since_refresh: 0,
            },
        );
        Some(())
    }

    /// Append one decoded token, growing by a page when crossing a boundary.
    pub fn append_token(&mut self, seq_id: u64) -> Option<()> {
        // Check growth need without holding a borrow across alloc.
        let needs_block = {
            let e = self.seqs.get(&seq_id).expect("unknown sequence");
            e.tokens % BLOCK_SIZE == 0 && e.tokens > 0
        };
        if needs_block {
            let blk = self.alloc.alloc()?;
            self.pages_acquired += 1;
            self.seqs.get_mut(&seq_id).unwrap().blocks.push(blk);
        }
        let e = self.seqs.get_mut(&seq_id).unwrap();
        e.tokens += 1;
        e.steps_since_refresh += 1;
        Some(())
    }

    /// Store the per-layer selections computed at prefill (or refresh).
    pub fn set_selections(&mut self, seq_id: u64, selections: Vec<Vec<usize>>) {
        let e = self.seqs.get_mut(&seq_id).expect("unknown sequence");
        assert_eq!(selections.len(), self.num_layers);
        e.selections = selections;
        e.steps_since_refresh = 0;
    }

    pub fn selections(&self, seq_id: u64) -> Option<&[Vec<usize>]> {
        self.seqs.get(&seq_id).map(|e| e.selections.as_slice())
    }

    pub fn steps_since_refresh(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|e| e.steps_since_refresh).unwrap_or(0)
    }

    /// Release a sequence: frees its pages and selections. Safe to call
    /// for an unknown id (cancellation/panic cleanup paths call it
    /// defensively).
    pub fn evict(&mut self, seq_id: u64) {
        if let Some(e) = self.seqs.remove(&seq_id) {
            self.pages_released += e.blocks.len();
            for b in e.blocks {
                self.alloc.release(b);
            }
        }
    }

    /// Grow the pool by `n` pages (budget reclaimed from the prefix cache
    /// under admission pressure — see `cache::PrefixCache::shed_pages`).
    pub fn grow(&mut self, n: usize) {
        self.alloc.add_blocks(n);
    }

    /// Lifetime pages handed to sequences (admission + decode growth).
    pub fn pages_acquired(&self) -> usize {
        self.pages_acquired
    }

    /// Lifetime pages returned by eviction.
    pub fn pages_released(&self) -> usize {
        self.pages_released
    }

    pub fn tokens(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|e| e.tokens).unwrap_or(0)
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn capacity(&self) -> usize {
        self.alloc.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::{run_property_noshrink, Config};

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(4);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        assert_eq!(a.free_blocks(), 2);
        a.retain(b1);
        a.release(b1);
        assert_eq!(a.refcount(b1), 1); // still held
        a.release(b1);
        a.release(b2);
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn withdraw_and_add_blocks_transfer_budget() {
        let mut a = BlockAllocator::new(4);
        let held = a.alloc().unwrap();
        assert_eq!(a.withdraw(10), 3, "only free blocks can leave");
        assert_eq!(a.capacity(), 1);
        assert_eq!(a.free_blocks(), 0);
        assert!(a.alloc().is_none());
        // The surviving allocation still releases cleanly.
        a.release(held);
        assert_eq!(a.free_blocks(), 1);
        let mut b = BlockAllocator::new(2);
        b.add_blocks(3);
        assert_eq!(b.capacity(), 5);
        assert_eq!(b.free_blocks(), 5);
        let ids: Vec<_> = (0..5).map(|_| b.alloc().unwrap()).collect();
        for id in ids {
            b.release(id);
        }
        assert_eq!(b.free_blocks(), 5);
    }

    #[test]
    fn manager_page_accounting_balances() {
        let mut kv = KvCacheManager::new(8, 1);
        kv.admit(1, 33).unwrap(); // 3 pages
        kv.admit(2, 16).unwrap(); // 1 page
        for _ in 0..17 {
            kv.append_token(1).unwrap(); // crosses two boundaries → +2
        }
        assert_eq!(kv.pages_acquired(), 6);
        assert_eq!(kv.pages_released(), 0);
        kv.evict(1);
        kv.evict(2);
        kv.evict(99); // unknown id: no-op, no double count
        assert_eq!(kv.pages_released(), kv.pages_acquired());
        assert_eq!(kv.free_blocks(), kv.capacity());
    }

    #[test]
    fn grow_admits_after_exhaustion() {
        let mut kv = KvCacheManager::new(2, 1);
        assert!(kv.admit(1, 40).is_none()); // needs 3 > 2
        kv.grow(2);
        assert!(kv.admit(1, 40).is_some()); // 3 <= 4 now
        assert_eq!(kv.capacity(), 4);
        kv.evict(1);
        assert_eq!(kv.free_blocks(), 4);
        assert_eq!(kv.pages_acquired(), kv.pages_released());
    }

    #[test]
    fn admit_allocates_pages() {
        let mut kv = KvCacheManager::new(8, 2);
        kv.admit(1, 33).unwrap(); // ceil(33/16) = 3 pages
        assert_eq!(kv.free_blocks(), 5);
        assert_eq!(kv.tokens(1), 33);
        kv.evict(1);
        assert_eq!(kv.free_blocks(), 8);
    }

    #[test]
    fn admit_fails_cleanly_when_full() {
        let mut kv = KvCacheManager::new(2, 1);
        assert!(kv.admit(1, 40).is_none()); // needs 3 > 2
        assert_eq!(kv.free_blocks(), 2); // nothing leaked
        assert!(kv.admit(2, 20).is_some()); // needs 2
    }

    #[test]
    fn append_grows_on_boundary() {
        let mut kv = KvCacheManager::new(4, 1);
        kv.admit(1, 16).unwrap(); // exactly one page
        assert_eq!(kv.free_blocks(), 3);
        kv.append_token(1).unwrap(); // crosses boundary → new page
        assert_eq!(kv.free_blocks(), 2);
        for _ in 0..15 {
            kv.append_token(1).unwrap(); // fills page, no new alloc
        }
        assert_eq!(kv.free_blocks(), 2);
        kv.append_token(1).unwrap(); // next boundary
        assert_eq!(kv.free_blocks(), 1);
    }

    #[test]
    fn selections_stored_and_refresh_counter() {
        let mut kv = KvCacheManager::new(8, 2);
        kv.admit(5, 10).unwrap();
        kv.set_selections(5, vec![vec![0, 3], vec![1, 2]]);
        assert_eq!(kv.selections(5).unwrap()[0], vec![0, 3]);
        assert_eq!(kv.steps_since_refresh(5), 0);
        kv.append_token(5).unwrap();
        kv.append_token(5).unwrap();
        assert_eq!(kv.steps_since_refresh(5), 2);
        kv.set_selections(5, vec![vec![0], vec![1]]);
        assert_eq!(kv.steps_since_refresh(5), 0);
    }

    #[test]
    fn property_no_leaks_no_double_free() {
        run_property_noshrink(
            "kv-cache-conservation",
            Config { cases: 40, ..Default::default() },
            |r| {
                // random op sequence: (admit len) / (append) / (evict)
                (0..r.range(5, 60))
                    .map(|_| (r.usize(3), r.range(1, 64), r.usize(6) as u64))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut kv = KvCacheManager::new(32, 2);
                let mut live: std::collections::HashSet<u64> = Default::default();
                for &(op, len, id) in ops {
                    match op {
                        0 => {
                            if !live.contains(&id) && kv.admit(id, len).is_some() {
                                live.insert(id);
                            }
                        }
                        1 => {
                            if live.contains(&id) {
                                let _ = kv.append_token(id);
                            }
                        }
                        _ => {
                            kv.evict(id);
                            live.remove(&id);
                        }
                    }
                    prop_assert!(kv.free_blocks() <= kv.capacity(), "free > capacity");
                }
                for id in live.iter() {
                    kv.evict(*id);
                }
                prop_assert!(
                    kv.free_blocks() == kv.capacity(),
                    "leak: {} free of {}",
                    kv.free_blocks(),
                    kv.capacity()
                );
                Ok(())
            },
        );
    }
}
