//! Dynamic batcher: groups queued requests into fixed-shape batches that
//! match the compiled PJRT artifacts.
//!
//! Policies (all invariant-tested, including by `proptest_lite`):
//! * a batch never exceeds `max_batch_tokens` (padded accounting: every lane
//!   costs `max_seq` tokens because the artifact shape is fixed);
//! * a batch never exceeds the largest available lane count, and lane counts
//!   are drawn from the compiled bucket list (e.g. {1, 4});
//! * FIFO admission — a request never overtakes an earlier one into a later
//!   batch;
//! * deadline flush: a non-empty batch is emitted once the oldest queued
//!   request has waited `deadline`.

use super::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Available artifact lane counts, ascending (e.g. [1, 4]).
    pub buckets: Vec<usize>,
    /// Padded token budget per batch.
    pub max_batch_tokens: usize,
    /// Artifact sequence length (every lane pads to this).
    pub max_seq: usize,
    /// Deadline before a partial batch is flushed.
    pub deadline: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 4],
            max_batch_tokens: 4096,
            max_seq: 256,
            deadline: Duration::from_millis(5),
        }
    }
}

/// An emitted batch: the requests plus the artifact lane count to use
/// (requests.len() <= lanes; the launcher pads the remainder).
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub lanes: usize,
}

/// FIFO dynamic batcher.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queue: VecDeque<Request>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(!cfg.buckets.is_empty(), "need at least one lane bucket");
        let mut cfg = cfg;
        cfg.buckets.sort_unstable();
        DynamicBatcher { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Time remaining until the oldest queued request hits the flush
    /// deadline (zero if already past it; `None` if the queue is empty).
    /// Lets the serving loop block exactly as long as the batching policy
    /// allows instead of polling on a fixed interval.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue
            .front()
            .map(|r| self.cfg.deadline.saturating_sub(now.saturating_duration_since(r.arrived)))
    }

    /// Max lanes that fit the token budget.
    fn budget_lanes(&self) -> usize {
        (self.cfg.max_batch_tokens / self.cfg.max_seq).max(1)
    }

    /// The largest compiled bucket not exceeding `want` (falls back to the
    /// smallest bucket so a single oversized request still ships alone).
    fn pick_bucket(&self, want: usize) -> usize {
        let mut best = self.cfg.buckets[0];
        for &b in &self.cfg.buckets {
            if b <= want {
                best = b;
            }
        }
        best
    }

    /// Emit the next batch if the policy says so: either a full bucket is
    /// ready, or the oldest request exceeded the deadline.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let cap = self.budget_lanes().min(*self.cfg.buckets.last().unwrap());
        let deadline_hit =
            now.duration_since(self.queue.front().unwrap().arrived) >= self.cfg.deadline;
        if self.queue.len() < cap && !deadline_hit {
            return None;
        }
        let lanes = self.pick_bucket(self.queue.len().min(cap));
        let take = lanes.min(self.queue.len());
        let requests: Vec<Request> = self.queue.drain(..take).collect();
        Some(Batch { requests, lanes })
    }

    /// Flush everything (shutdown path), respecting bucket shapes.
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let cap = self.budget_lanes().min(*self.cfg.buckets.last().unwrap());
            let lanes = self.pick_bucket(self.queue.len().min(cap));
            let take = lanes.min(self.queue.len());
            let requests: Vec<Request> = self.queue.drain(..take).collect();
            out.push(Batch { requests, lanes });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::{run_property_noshrink, Config};

    fn req(id: u64, n: usize) -> Request {
        Request::scoring(id, vec![0; n])
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            buckets: vec![1, 4],
            max_batch_tokens: 1024,
            max_seq: 256,
            deadline: Duration::from_millis(5),
        }
    }

    #[test]
    fn full_bucket_ships_immediately() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..4 {
            b.push(req(i, 100));
        }
        let batch = b.poll(Instant::now()).expect("full bucket should ship");
        assert_eq!(batch.lanes, 4);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn partial_waits_for_deadline() {
        let mut b = DynamicBatcher::new(cfg());
        b.push(req(0, 100));
        assert!(b.poll(Instant::now()).is_none(), "should wait for deadline");
        let later = Instant::now() + Duration::from_millis(50);
        let batch = b.poll(later).expect("deadline flush");
        assert_eq!(batch.lanes, 1);
    }

    #[test]
    fn time_to_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.time_to_deadline(Instant::now()).is_none(), "empty queue");
        b.push(req(0, 100));
        let now = Instant::now();
        let remaining = b.time_to_deadline(now).unwrap();
        assert!(remaining <= Duration::from_millis(5));
        // Past the deadline: saturates to zero instead of panicking.
        let later = now + Duration::from_millis(50);
        assert_eq!(b.time_to_deadline(later).unwrap(), Duration::ZERO);
    }

    #[test]
    fn deadline_flush_picks_largest_fitting_bucket() {
        let mut b = DynamicBatcher::new(cfg());
        b.push(req(0, 10));
        b.push(req(1, 10));
        b.push(req(2, 10));
        let later = Instant::now() + Duration::from_millis(50);
        let batch = b.poll(later).unwrap();
        // 3 queued → bucket 1 (largest ≤ 3 among {1,4} is 1)
        assert_eq!(batch.lanes, 1);
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.queue_len(), 2);
    }

    #[test]
    fn fifo_preserved() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..8 {
            b.push(req(i, 10));
        }
        let b1 = b.poll(Instant::now()).unwrap();
        let b2 = b.poll(Instant::now()).unwrap();
        let ids1: Vec<u64> = b1.requests.iter().map(|r| r.id).collect();
        let ids2: Vec<u64> = b2.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids1, vec![0, 1, 2, 3]);
        assert_eq!(ids2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn token_budget_bounds_lanes() {
        // budget 1024 / seq 256 = 4 lanes max; with seq 512 only 2 lanes.
        let c = BatcherConfig { max_seq: 512, ..cfg() };
        let mut b = DynamicBatcher::new(c);
        for i in 0..4 {
            b.push(req(i, 100));
        }
        let batch = b.poll(Instant::now()).unwrap();
        assert!(batch.lanes <= 2);
    }

    #[test]
    fn drain_all_empties_queue() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..6 {
            b.push(req(i, 10));
        }
        let batches = b.drain_all();
        let total: usize = batches.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 6);
        assert_eq!(b.queue_len(), 0);
        for batch in &batches {
            assert!(batch.requests.len() <= batch.lanes);
        }
    }

    #[test]
    fn property_batches_respect_budget_and_fifo() {
        run_property_noshrink(
            "batcher-invariants",
            Config { cases: 50, ..Default::default() },
            |r| {
                let n = r.range(1, 40);
                (0..n).map(|i| (i as u64, r.range(1, 257))).collect::<Vec<_>>()
            },
            |reqs| {
                let mut b = DynamicBatcher::new(cfg());
                for &(id, len) in reqs {
                    b.push(req(id, len));
                }
                let mut seen: Vec<u64> = Vec::new();
                let far = Instant::now() + Duration::from_secs(10);
                while let Some(batch) = b.poll(far) {
                    prop_assert!(
                        batch.lanes * 256 <= 1024,
                        "token budget exceeded: {} lanes",
                        batch.lanes
                    );
                    prop_assert!(
                        batch.requests.len() <= batch.lanes,
                        "more requests than lanes"
                    );
                    prop_assert!(
                        [1usize, 4].contains(&batch.lanes),
                        "lane count {} not a compiled bucket",
                        batch.lanes
                    );
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
                let want: Vec<u64> = reqs.iter().map(|&(id, _)| id).collect();
                prop_assert!(seen == want, "FIFO violated: {seen:?} vs {want:?}");
                Ok(())
            },
        );
    }
}
