//! The L3 serving coordinator — the systems half of the paper's
//! contribution: pre-scoring as a *first-class feature of the serving
//! stack*, per §3.1's computational perspective ("pre-scoring is performed
//! during the prefill stage; during token-by-token decoding we reuse this
//! selection or update it only periodically").
//!
//! Components (vLLM-router-shaped):
//! * [`request`] — request/response types and lifecycle states;
//! * [`batcher`] — dynamic batching with token budget, deadline flush, and
//!   padded-shape buckets matching the compiled artifact batch sizes;
//! * [`kv_cache`] — block-allocated KV store with ref-counting (page size
//!   16) that also owns the per-(sequence, layer) key-selection sets;
//! * [`kv_quant`] — quantized KV storage (`kv_dtype = f16|int8`): fake-quant
//!   grids for live sessions, lossless-slicing packed pages for the
//!   prefix-cache and disk tiers;
//! * [`prescore_manager`] — Algorithm 1 at prefill, cached selection with
//!   periodic refresh during decode, Algorithm 2's δ-fallback;
//! * [`scheduler`] — prefill/decode queues with a decode-starvation bound.

pub mod batcher;
pub mod kv_cache;
pub mod kv_quant;
pub mod prescore_manager;
pub mod request;
pub mod scheduler;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use kv_cache::{BlockAllocator, KvCacheManager};
pub use kv_quant::{KvDtype, KvStore, QuantKv};
pub use prescore_manager::{PreScoreManager, PreScoreManagerConfig};
pub use request::{Request, RequestId, RequestState, Response, ServerError};
pub use scheduler::{Scheduler, SchedulerConfig, WorkItem};
