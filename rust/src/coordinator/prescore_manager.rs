//! Pre-score manager: Algorithm 1 at prefill, cached + periodically
//! refreshed during decode, with Algorithm 2's δ-fallback.
//!
//! §3.1: "For autoregressive decoding, pre-scoring is performed during the
//! prefill stage; during token-by-token decoding we reuse this selection (or
//! update it only periodically), avoiding an O(n) clustering pass at every
//! step."

use crate::linalg::Matrix;
use crate::prescore::{prescore, KeyBudget, Method, PreScoreConfig, PreScoreResult};

/// Policy configuration.
#[derive(Debug, Clone)]
pub struct PreScoreManagerConfig {
    pub method: Method,
    pub budget: KeyBudget,
    /// Refresh the cached selection every R decode steps (0 = never).
    pub refresh_every: usize,
    /// Algorithm 2 fallback threshold δ: selection below δ·n disables
    /// filtering for that layer.
    pub fallback_delta: f32,
    pub seed: u64,
}

impl Default for PreScoreManagerConfig {
    fn default() -> Self {
        PreScoreManagerConfig {
            method: Method::KMeans,
            budget: KeyBudget::Fixed(64),
            refresh_every: 16,
            fallback_delta: 0.0,
            seed: 0,
        }
    }
}

impl PreScoreManagerConfig {
    /// Build from the serving config's legacy `[prescore]` keys — the
    /// decode engine's refresh policy source.
    pub fn from_serving(cfg: &crate::config::ServingConfig) -> anyhow::Result<Self> {
        let method = Method::parse(&cfg.prescore_method).ok_or_else(|| {
            anyhow::anyhow!("unknown [prescore] method '{}'", cfg.prescore_method)
        })?;
        Ok(PreScoreManagerConfig {
            method,
            budget: if cfg.prescore_mass > 0.0 {
                KeyBudget::Mass(cfg.prescore_mass as f32)
            } else {
                KeyBudget::Fixed(cfg.prescore_top_k)
            },
            refresh_every: cfg.prescore_refresh_every,
            fallback_delta: cfg.fallback_delta as f32,
            seed: 0,
        })
    }
}

/// Outcome of a selection decision for one layer.
#[derive(Debug, Clone)]
pub struct SelectionDecision {
    pub selected: Vec<usize>,
    pub fallback_used: bool,
}

/// The manager itself is stateless over sequences (state lives in the
/// KV-cache manager); it encapsulates the policy.
pub struct PreScoreManager {
    pub cfg: PreScoreManagerConfig,
}

impl PreScoreManager {
    pub fn new(cfg: PreScoreManagerConfig) -> Self {
        PreScoreManager { cfg }
    }

    /// Run Algorithm 1 on one layer's key matrix at prefill.
    pub fn select(&self, keys: &Matrix, layer: usize) -> SelectionDecision {
        let n = keys.rows;
        let ps_cfg = PreScoreConfig {
            method: self.cfg.method,
            budget: self.cfg.budget,
            seed: self.cfg.seed.wrapping_add(layer as u64),
            ..Default::default()
        };
        let r: PreScoreResult = prescore(keys, &ps_cfg);
        // Algorithm 2 line 2: fallback when |S| < δ·n.
        if (r.selected.len() as f32) < self.cfg.fallback_delta * n as f32 {
            return SelectionDecision { selected: (0..n).collect(), fallback_used: true };
        }
        SelectionDecision { selected: r.selected, fallback_used: false }
    }

    /// Decode-time policy: does the cached selection need a refresh?
    pub fn needs_refresh(&self, steps_since_refresh: usize) -> bool {
        self.cfg.refresh_every > 0 && steps_since_refresh >= self.cfg.refresh_every
    }

    /// Extend a cached selection with a freshly decoded position without
    /// re-clustering: new tokens are always visible until the next refresh
    /// (they cannot have been scored yet, and recency is a strong prior).
    pub fn extend_with_new_token(&self, selected: &mut Vec<usize>, new_pos: usize) {
        if selected.last() != Some(&new_pos) {
            selected.push(new_pos);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn keys(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(n, d, 1.0, &mut rng)
    }

    #[test]
    fn select_returns_budget() {
        let m = PreScoreManager::new(PreScoreManagerConfig { budget: KeyBudget::Fixed(16), ..Default::default() });
        let k = keys(128, 8, 1);
        let d = m.select(&k, 0);
        assert_eq!(d.selected.len(), 16);
        assert!(!d.fallback_used);
    }

    #[test]
    fn fallback_triggers() {
        let m = PreScoreManager::new(PreScoreManagerConfig {
            budget: KeyBudget::Fixed(4),
            fallback_delta: 0.5, // 4 < 0.5·128
            ..Default::default()
        });
        let k = keys(128, 8, 2);
        let d = m.select(&k, 0);
        assert!(d.fallback_used);
        assert_eq!(d.selected.len(), 128);
    }

    #[test]
    fn refresh_policy() {
        let m = PreScoreManager::new(PreScoreManagerConfig { refresh_every: 8, ..Default::default() });
        assert!(!m.needs_refresh(7));
        assert!(m.needs_refresh(8));
        assert!(m.needs_refresh(100));
        let never = PreScoreManager::new(PreScoreManagerConfig { refresh_every: 0, ..Default::default() });
        assert!(!never.needs_refresh(10_000));
    }

    #[test]
    fn per_layer_seeds_differ() {
        let m = PreScoreManager::new(PreScoreManagerConfig { budget: KeyBudget::Fixed(8), ..Default::default() });
        let k = keys(256, 8, 3);
        let d0 = m.select(&k, 0);
        let d0b = m.select(&k, 0);
        assert_eq!(d0.selected, d0b.selected, "same layer must be deterministic");
    }

    #[test]
    fn extend_appends_new_position() {
        let m = PreScoreManager::new(Default::default());
        let mut sel = vec![0, 5, 9];
        m.extend_with_new_token(&mut sel, 12);
        assert_eq!(sel, vec![0, 5, 9, 12]);
        m.extend_with_new_token(&mut sel, 12); // idempotent
        assert_eq!(sel, vec![0, 5, 9, 12]);
    }
}
