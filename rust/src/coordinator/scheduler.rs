//! Prefill/decode scheduler with a decode-starvation bound and per-tenant
//! deficit-round-robin lanes.
//!
//! Prefill work is throughput-critical (it fills lanes), decode work is
//! latency-critical (it extends live sequences). The policy is
//! prefill-priority with a starvation bound: after `max_prefill_streak`
//! consecutive prefill dispatches with decode work pending, a decode round
//! is forced.
//!
//! Work is submitted into *lanes* (one per tenant; the engine maps tenant
//! keys to lane indices, lane 0 is the anonymous default). Within the
//! prefill/decode class, lanes are served deficit-round-robin: each
//! non-empty lane earns one credit per scheduler visit and a batch is
//! dispatched once the lane's deficit covers the batch size, so a tenant
//! queueing many requests cannot head-of-line-block a tenant queueing one.
//! Decode rounds take at most one sequence per lane per sweep, so a round
//! of width `w` mixes up to `w` distinct tenants. With a single active lane
//! the scheduler behaves exactly like the plain FIFO policy.

use std::collections::VecDeque;

/// What the scheduler hands to the execution loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkItem {
    /// Run prefill for these request ids.
    Prefill(Vec<u64>),
    /// Run one decode step for these sequence ids.
    Decode(Vec<u64>),
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Force a decode round after this many consecutive prefill rounds
    /// while decode work is waiting.
    pub max_prefill_streak: usize,
    /// Max sequences per decode round.
    pub decode_width: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_prefill_streak: 4, decode_width: 8 }
    }
}

/// One tenant's queues plus its DRR credit.
#[derive(Debug, Default)]
struct Lane {
    prefill: VecDeque<Vec<u64>>,
    decode: VecDeque<u64>,
    deficit: usize,
}

/// The scheduler state.
pub struct Scheduler {
    cfg: SchedulerConfig,
    lanes: Vec<Lane>,
    /// DRR cursors: the lane index each class visits first on its next pop.
    prefill_rr: usize,
    decode_rr: usize,
    prefill_streak: usize,
    pending_prefill_batches: usize,
    pending_decode_ids: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler {
            cfg,
            lanes: Vec::new(),
            prefill_rr: 0,
            decode_rr: 0,
            prefill_streak: 0,
            pending_prefill_batches: 0,
            pending_decode_ids: 0,
        }
    }

    fn ensure_lane(&mut self, lane: usize) {
        while self.lanes.len() <= lane {
            self.lanes.push(Lane::default());
        }
    }

    /// Enqueue a prefill batch (ids grouped by the dynamic batcher) into the
    /// anonymous lane.
    pub fn submit_prefill(&mut self, ids: Vec<u64>) {
        self.submit_prefill_for(0, ids);
    }

    /// Enqueue a prefill batch into a tenant lane.
    pub fn submit_prefill_for(&mut self, lane: usize, ids: Vec<u64>) {
        self.ensure_lane(lane);
        self.pending_prefill_batches += 1;
        self.lanes[lane].prefill.push_back(ids);
    }

    /// Enqueue a sequence for decoding into the anonymous lane.
    pub fn submit_decode(&mut self, seq_id: u64) {
        self.submit_decode_for(0, seq_id);
    }

    /// Enqueue a sequence for decoding into a tenant lane.
    pub fn submit_decode_for(&mut self, lane: usize, seq_id: u64) {
        self.ensure_lane(lane);
        self.pending_decode_ids += 1;
        self.lanes[lane].decode.push_back(seq_id);
    }

    /// Queued prefill batches across all lanes.
    pub fn pending_prefill(&self) -> usize {
        self.pending_prefill_batches
    }

    /// Queued decode sequence ids across all lanes.
    pub fn pending_decode(&self) -> usize {
        self.pending_decode_ids
    }

    /// Pop the next prefill batch deficit-round-robin across lanes. Each
    /// sweep grants every non-empty lane one credit; a lane's head batch is
    /// served once its deficit covers the batch size, so big-batch tenants
    /// wait proportionally longer. Terminates because every sweep over a
    /// non-empty scheduler strictly grows some eligible lane's deficit.
    fn pop_prefill(&mut self) -> Option<Vec<u64>> {
        if self.pending_prefill_batches == 0 {
            return None;
        }
        let n = self.lanes.len();
        loop {
            for step in 0..n {
                let i = (self.prefill_rr + step) % n;
                let lane = &mut self.lanes[i];
                let Some(head) = lane.prefill.front() else {
                    lane.deficit = 0;
                    continue;
                };
                lane.deficit += 1;
                let cost = head.len().max(1);
                if lane.deficit >= cost {
                    lane.deficit -= cost;
                    if lane.prefill.len() == 1 {
                        lane.deficit = 0;
                    }
                    self.prefill_rr = (i + 1) % n;
                    self.pending_prefill_batches -= 1;
                    return lane.prefill.pop_front();
                }
            }
        }
    }

    /// Assemble one decode round: sweep the lanes round-robin, taking one
    /// sequence per non-empty lane per sweep, until `decode_width` ids are
    /// collected or the queues drain.
    fn pop_decode_round(&mut self) -> Vec<u64> {
        let width = self.cfg.decode_width.min(self.pending_decode_ids);
        let mut ids = Vec::with_capacity(width);
        let n = self.lanes.len();
        'outer: while ids.len() < width {
            let mut any = false;
            for step in 0..n {
                let i = (self.decode_rr + step) % n;
                let Some(id) = self.lanes[i].decode.pop_front() else {
                    continue;
                };
                any = true;
                ids.push(id);
                self.pending_decode_ids -= 1;
                if ids.len() >= width {
                    self.decode_rr = (i + 1) % n;
                    break 'outer;
                }
            }
            if !any {
                break;
            }
        }
        ids
    }

    /// Next work item under prefill-priority + starvation bound.
    pub fn next(&mut self) -> Option<WorkItem> {
        let decode_waiting = self.pending_decode_ids > 0;
        let force_decode = decode_waiting && self.prefill_streak >= self.cfg.max_prefill_streak;
        if !force_decode {
            if let Some(ids) = self.pop_prefill() {
                self.prefill_streak += 1;
                return Some(WorkItem::Prefill(ids));
            }
        }
        if decode_waiting {
            self.prefill_streak = 0;
            return Some(WorkItem::Decode(self.pop_decode_round()));
        }
        // Forced decode path never reaches here (decode_waiting guard), so
        // this trailing pop only serves the force_decode && !decode_waiting
        // corner, which is unreachable — kept for symmetry with `next`'s
        // original shape.
        if let Some(ids) = self.pop_prefill() {
            self.prefill_streak += 1;
            return Some(WorkItem::Prefill(ids));
        }
        None
    }

    /// Pool-aware dispatch: pull up to `free_workers` work items in one call
    /// so the execution loop can top up every idle executor worker per
    /// scheduling round. The prefill-priority / starvation-bound policy of
    /// [`Scheduler::next`] applies item by item, so a round mixes prefill
    /// and decode exactly as the serial policy would have dispatched them.
    pub fn next_round(&mut self, free_workers: usize) -> Vec<WorkItem> {
        let mut round = Vec::with_capacity(free_workers.min(8));
        for _ in 0..free_workers {
            match self.next() {
                Some(item) => round.push(item),
                None => break,
            }
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::{run_property_noshrink, Config};

    #[test]
    fn prefill_priority() {
        let mut s = Scheduler::new(Default::default());
        s.submit_decode(1);
        s.submit_prefill(vec![10]);
        assert_eq!(s.next(), Some(WorkItem::Prefill(vec![10])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![1])));
        assert_eq!(s.next(), None);
    }

    #[test]
    fn starvation_bound_forces_decode() {
        let cfg = SchedulerConfig { max_prefill_streak: 2, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        s.submit_decode(99);
        for i in 0..5 {
            s.submit_prefill(vec![i]);
        }
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
        // streak = 2 ⇒ decode forced even though prefill is pending
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![99])));
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
    }

    #[test]
    fn decode_width_bounds_round() {
        let cfg = SchedulerConfig { max_prefill_streak: 1, decode_width: 3 };
        let mut s = Scheduler::new(cfg);
        for i in 0..7 {
            s.submit_decode(i);
        }
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![0, 1, 2])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![3, 4, 5])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![6])));
    }

    #[test]
    fn next_round_fills_pool_and_respects_policy() {
        let cfg = SchedulerConfig { max_prefill_streak: 2, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        s.submit_decode(9);
        for i in 0..4 {
            s.submit_prefill(vec![i]);
        }
        // 4 free workers: two prefills, then the starvation bound forces the
        // decode, then prefill resumes.
        let round = s.next_round(4);
        assert_eq!(round.len(), 4);
        assert!(matches!(round[0], WorkItem::Prefill(_)));
        assert!(matches!(round[1], WorkItem::Prefill(_)));
        assert_eq!(round[2], WorkItem::Decode(vec![9]));
        assert!(matches!(round[3], WorkItem::Prefill(_)));
        // Remaining work drains on the following round; zero workers = noop.
        assert!(s.next_round(0).is_empty());
        assert_eq!(s.next_round(8).len(), 1);
        assert!(s.next_round(8).is_empty());
    }

    #[test]
    fn decode_round_interleaves_lanes() {
        let cfg = SchedulerConfig { max_prefill_streak: 1, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        // Lane 0 floods, lane 1 queues two.
        for i in 0..6 {
            s.submit_decode_for(0, i);
        }
        s.submit_decode_for(1, 100);
        s.submit_decode_for(1, 101);
        // One id per lane per sweep: lane 1 appears in every round until it
        // drains, despite being outnumbered 3:1.
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![0, 100, 1, 101])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![2, 3, 4, 5])));
        assert_eq!(s.next(), None);
        assert_eq!(s.pending_decode(), 0);
    }

    #[test]
    fn prefill_drr_prevents_head_of_line_blocking() {
        let cfg = SchedulerConfig { max_prefill_streak: 100, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        // Tenant 0 queues four singleton batches before tenant 1's arrives.
        for i in 0..4 {
            s.submit_prefill_for(0, vec![i]);
        }
        s.submit_prefill_for(1, vec![50]);
        let mut order = Vec::new();
        while let Some(WorkItem::Prefill(ids)) = s.next() {
            order.push(ids[0]);
        }
        let pos = order.iter().position(|&id| id == 50);
        // Tenant 1's lone batch is served within the first sweep, not after
        // tenant 0's whole backlog.
        assert!(pos.is_some_and(|p| p <= 1), "tenant 1 starved: order {order:?}");
        assert_eq!(order.len(), 5, "no batch lost");
    }

    #[test]
    fn single_lane_keeps_fifo_order() {
        let cfg = SchedulerConfig { max_prefill_streak: 100, decode_width: 8 };
        let mut s = Scheduler::new(cfg);
        for i in 0..5 {
            s.submit_prefill(vec![i, i + 10]);
        }
        for i in 0..5 {
            assert_eq!(s.next(), Some(WorkItem::Prefill(vec![i, i + 10])));
        }
        assert_eq!(s.next(), None);
    }

    #[test]
    fn property_nothing_lost_and_starvation_bounded() {
        run_property_noshrink(
            "scheduler-invariants",
            Config { cases: 40, ..Default::default() },
            |r| {
                (0..r.range(1, 80))
                    .map(|i| (r.bool(0.5), r.range(0, 3), i as u64))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let cfg = SchedulerConfig { max_prefill_streak: 3, decode_width: 2 };
                let mut s = Scheduler::new(cfg);
                let mut submitted_p = 0usize;
                let mut submitted_d = 0usize;
                for &(is_prefill, lane, id) in ops {
                    if is_prefill {
                        s.submit_prefill_for(lane, vec![id]);
                        submitted_p += 1;
                    } else {
                        s.submit_decode_for(lane, id);
                        submitted_d += 1;
                    }
                }
                let mut got_p = 0usize;
                let mut got_d = 0usize;
                let mut streak = 0usize;
                while let Some(item) = s.next() {
                    match item {
                        WorkItem::Prefill(ids) => {
                            got_p += ids.len();
                            streak += 1;
                            prop_assert!(
                                streak <= 3 || s.pending_decode() == 0,
                                "prefill streak {} with decode pending",
                                streak
                            );
                        }
                        WorkItem::Decode(ids) => {
                            got_d += ids.len();
                            streak = 0;
                        }
                    }
                }
                prop_assert!(got_p == submitted_p, "lost prefill work");
                prop_assert!(got_d == submitted_d, "lost decode work");
                Ok(())
            },
        );
    }
}
