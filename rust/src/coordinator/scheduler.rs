//! Prefill/decode scheduler with a decode-starvation bound.
//!
//! Prefill work is throughput-critical (it fills lanes), decode work is
//! latency-critical (it extends live sequences). The policy is
//! prefill-priority with a starvation bound: after `max_prefill_streak`
//! consecutive prefill dispatches with decode work pending, a decode round
//! is forced.

use std::collections::VecDeque;

/// What the scheduler hands to the execution loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkItem {
    /// Run prefill for these request ids.
    Prefill(Vec<u64>),
    /// Run one decode step for these sequence ids.
    Decode(Vec<u64>),
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Force a decode round after this many consecutive prefill rounds
    /// while decode work is waiting.
    pub max_prefill_streak: usize,
    /// Max sequences per decode round.
    pub decode_width: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_prefill_streak: 4, decode_width: 8 }
    }
}

/// The scheduler state.
pub struct Scheduler {
    cfg: SchedulerConfig,
    prefill_q: VecDeque<Vec<u64>>,
    decode_q: VecDeque<u64>,
    prefill_streak: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, prefill_q: VecDeque::new(), decode_q: VecDeque::new(), prefill_streak: 0 }
    }

    /// Enqueue a prefill batch (ids grouped by the dynamic batcher).
    pub fn submit_prefill(&mut self, ids: Vec<u64>) {
        self.prefill_q.push_back(ids);
    }

    /// Enqueue a sequence for decoding.
    pub fn submit_decode(&mut self, seq_id: u64) {
        self.decode_q.push_back(seq_id);
    }

    pub fn pending_prefill(&self) -> usize {
        self.prefill_q.len()
    }

    pub fn pending_decode(&self) -> usize {
        self.decode_q.len()
    }

    /// Next work item under prefill-priority + starvation bound.
    pub fn next(&mut self) -> Option<WorkItem> {
        let decode_waiting = !self.decode_q.is_empty();
        let force_decode = decode_waiting && self.prefill_streak >= self.cfg.max_prefill_streak;
        if !force_decode {
            if let Some(ids) = self.prefill_q.pop_front() {
                self.prefill_streak += 1;
                return Some(WorkItem::Prefill(ids));
            }
        }
        if decode_waiting {
            self.prefill_streak = 0;
            let take = self.cfg.decode_width.min(self.decode_q.len());
            let ids: Vec<u64> = self.decode_q.drain(..take).collect();
            return Some(WorkItem::Decode(ids));
        }
        // Nothing to do (or forced decode with empty decode queue — cannot
        // happen given decode_waiting guard).
        if let Some(ids) = self.prefill_q.pop_front() {
            self.prefill_streak += 1;
            return Some(WorkItem::Prefill(ids));
        }
        None
    }

    /// Pool-aware dispatch: pull up to `free_workers` work items in one call
    /// so the execution loop can top up every idle executor worker per
    /// scheduling round. The prefill-priority / starvation-bound policy of
    /// [`Scheduler::next`] applies item by item, so a round mixes prefill
    /// and decode exactly as the serial policy would have dispatched them.
    pub fn next_round(&mut self, free_workers: usize) -> Vec<WorkItem> {
        let mut round = Vec::with_capacity(free_workers.min(8));
        for _ in 0..free_workers {
            match self.next() {
                Some(item) => round.push(item),
                None => break,
            }
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::{run_property_noshrink, Config};

    #[test]
    fn prefill_priority() {
        let mut s = Scheduler::new(Default::default());
        s.submit_decode(1);
        s.submit_prefill(vec![10]);
        assert_eq!(s.next(), Some(WorkItem::Prefill(vec![10])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![1])));
        assert_eq!(s.next(), None);
    }

    #[test]
    fn starvation_bound_forces_decode() {
        let cfg = SchedulerConfig { max_prefill_streak: 2, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        s.submit_decode(99);
        for i in 0..5 {
            s.submit_prefill(vec![i]);
        }
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
        // streak = 2 ⇒ decode forced even though prefill is pending
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![99])));
        assert!(matches!(s.next(), Some(WorkItem::Prefill(_))));
    }

    #[test]
    fn decode_width_bounds_round() {
        let cfg = SchedulerConfig { max_prefill_streak: 1, decode_width: 3 };
        let mut s = Scheduler::new(cfg);
        for i in 0..7 {
            s.submit_decode(i);
        }
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![0, 1, 2])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![3, 4, 5])));
        assert_eq!(s.next(), Some(WorkItem::Decode(vec![6])));
    }

    #[test]
    fn next_round_fills_pool_and_respects_policy() {
        let cfg = SchedulerConfig { max_prefill_streak: 2, decode_width: 4 };
        let mut s = Scheduler::new(cfg);
        s.submit_decode(9);
        for i in 0..4 {
            s.submit_prefill(vec![i]);
        }
        // 4 free workers: two prefills, then the starvation bound forces the
        // decode, then prefill resumes.
        let round = s.next_round(4);
        assert_eq!(round.len(), 4);
        assert!(matches!(round[0], WorkItem::Prefill(_)));
        assert!(matches!(round[1], WorkItem::Prefill(_)));
        assert_eq!(round[2], WorkItem::Decode(vec![9]));
        assert!(matches!(round[3], WorkItem::Prefill(_)));
        // Remaining work drains on the following round; zero workers = noop.
        assert!(s.next_round(0).is_empty());
        assert_eq!(s.next_round(8).len(), 1);
        assert!(s.next_round(8).is_empty());
    }

    #[test]
    fn property_nothing_lost_and_starvation_bounded() {
        run_property_noshrink(
            "scheduler-invariants",
            Config { cases: 40, ..Default::default() },
            |r| {
                (0..r.range(1, 80))
                    .map(|i| (r.bool(0.5), i as u64))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let cfg = SchedulerConfig { max_prefill_streak: 3, decode_width: 2 };
                let mut s = Scheduler::new(cfg);
                let mut submitted_p = 0usize;
                let mut submitted_d = 0usize;
                for &(is_prefill, id) in ops {
                    if is_prefill {
                        s.submit_prefill(vec![id]);
                        submitted_p += 1;
                    } else {
                        s.submit_decode(id);
                        submitted_d += 1;
                    }
                }
                let mut got_p = 0usize;
                let mut got_d = 0usize;
                let mut streak = 0usize;
                while let Some(item) = s.next() {
                    match item {
                        WorkItem::Prefill(ids) => {
                            got_p += ids.len();
                            streak += 1;
                            prop_assert!(
                                streak <= 3 || s.pending_decode() == 0,
                                "prefill streak {} with decode pending",
                                streak
                            );
                        }
                        WorkItem::Decode(ids) => {
                            got_d += ids.len();
                            streak = 0;
                        }
                    }
                }
                prop_assert!(got_p == submitted_p, "lost prefill work");
                prop_assert!(got_d == submitted_d, "lost decode work");
                Ok(())
            },
        );
    }
}
