//! Request/response types and lifecycle.

use std::fmt;
use std::time::{Duration, Instant};

pub type RequestId = u64;

/// Lifecycle of a request inside the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting in the prefill queue.
    Queued,
    /// Selected into a prefill batch (pre-scoring runs here).
    Prefilling,
    /// In the decode loop (selection cached, refreshed periodically).
    Decoding,
    /// Finished; response delivered.
    Completed,
    /// Rejected/failed (e.g., over max_seq).
    Failed,
}

/// A scoring/generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Tokens to greedily generate after scoring (0 = scoring only).
    pub generate: usize,
    pub arrived: Instant,
    pub state: RequestState,
    /// Wall-clock budget from `arrived`, in milliseconds (0 = no deadline).
    /// An expired request is failed with [`ServerError::DeadlineExceeded`]
    /// and its KV pages / prefix pins are released.
    pub deadline_ms: u64,
    /// Fairness/accounting key (empty = anonymous). The scheduler orders
    /// work deficit-round-robin across tenants, and `ServerStats::tenants`
    /// breaks the terminal counters down per tenant.
    pub tenant: String,
}

impl Request {
    pub fn scoring(id: RequestId, tokens: Vec<u32>) -> Self {
        Request {
            id,
            tokens,
            generate: 0,
            arrived: Instant::now(),
            state: RequestState::Queued,
            deadline_ms: 0,
            tenant: String::new(),
        }
    }

    /// Builder: attach a deadline (milliseconds from arrival).
    pub fn with_deadline(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = deadline_ms;
        self
    }

    /// Builder: tag the request with a tenant key for fair scheduling and
    /// per-tenant stats.
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    /// The absolute deadline, if one was set.
    pub fn deadline(&self) -> Option<Instant> {
        (self.deadline_ms > 0)
            .then(|| self.arrived + Duration::from_millis(self.deadline_ms))
    }

    /// Has the deadline passed?
    pub fn expired(&self) -> bool {
        self.deadline().map_or(false, |d| Instant::now() >= d)
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }
}

/// Typed failure classes threaded into [`Response::error`]. A failed
/// request gets a response (never a silently dropped channel), and the
/// class tells the client whether to retry (Capacity), fix the request
/// (Invalid/Unsupported), or treat it as served-as-asked (Cancelled /
/// DeadlineExceeded).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// Cancelled via `ScoringServer::cancel` before completion.
    Cancelled,
    /// The request's `deadline_ms` elapsed before completion.
    DeadlineExceeded,
    /// Admission refused: the request cannot fit, or load-shedding runs in
    /// reject mode and the pool is saturated.
    Capacity(String),
    /// Malformed request (e.g. an empty token stream).
    Invalid(String),
    /// This server cannot serve the request class (e.g. generation without
    /// a substrate model).
    Unsupported(String),
    /// A worker panicked or an internal component failed. The request is
    /// dead; the server keeps serving.
    Internal(String),
}

impl fmt::Display for ServerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServerError::Cancelled => write!(f, "cancelled"),
            ServerError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServerError::Capacity(m) => write!(f, "over capacity: {m}"),
            ServerError::Invalid(m) => write!(f, "invalid request: {m}"),
            ServerError::Unsupported(m) => write!(f, "unsupported: {m}"),
            ServerError::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for ServerError {}

/// The response returned to the client.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// Per-token NLL over the scored context (length = context − 1).
    pub nll: Vec<f32>,
    /// Greedily generated continuation (empty for scoring-only).
    pub generated: Vec<u32>,
    /// Time-to-first-result in milliseconds.
    pub latency_ms: f64,
    /// Attention kernel that served this request (AttnStats::kernel).
    pub kernel: String,
    /// Keys the attention backend retained for this request's context
    /// (= context length when the backend is unfiltered or fell back).
    pub retained_keys: usize,
    /// Realized key budget across this request's layer·head selection
    /// states: mean / p50 / p99 of the per-state retained-key counts at the
    /// terminal step. Fixed budgets realize their `top_k`; `mass=` budgets
    /// realize whatever the score distribution demanded. All equal to
    /// `retained_keys` for kernels without per-state selections.
    pub realized_keys_mean: f64,
    pub realized_keys_p50: usize,
    pub realized_keys_p99: usize,
    /// Algorithm 2 line 2: the δ-fallback disabled filtering.
    pub fallback_used: bool,
    /// Tokens produced through the incremental decode path (0 for
    /// scoring-only requests served by the prefill/artifact path).
    pub decode_steps: usize,
    /// Total wall time spent inside decode steps for this request (ms) —
    /// per-step p50/p99 across requests lives in `ServerStats`.
    pub decode_ms: f64,
    /// Load-shedding served this request down the degradation ladder:
    /// `spec` names the spec that actually ran (truthful degradation — the
    /// client is never silently served a sparser budget).
    pub degraded: bool,
    /// Attention spec string this request was actually served under.
    pub spec: String,
    /// Why the request failed, if it did. `None` = served successfully.
    /// A faulted decode still reports its partial `generated`/`nll`.
    pub error: Option<ServerError>,
}

impl Response {
    /// Request-level perplexity.
    pub fn perplexity(&self) -> f64 {
        if self.nll.is_empty() {
            return f64::NAN;
        }
        (self.nll.iter().map(|&v| v as f64).sum::<f64>() / self.nll.len() as f64).exp()
    }

    /// A typed failure response with no payload.
    pub fn failure(id: RequestId, latency_ms: f64, spec: String, error: ServerError) -> Response {
        Response {
            id,
            nll: Vec::new(),
            generated: Vec::new(),
            latency_ms,
            kernel: String::new(),
            retained_keys: 0,
            realized_keys_mean: 0.0,
            realized_keys_p50: 0,
            realized_keys_p99: 0,
            fallback_used: false,
            decode_steps: 0,
            decode_ms: 0.0,
            degraded: false,
            spec,
            error: Some(error),
        }
    }

    /// Did the request complete successfully?
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::scoring(7, vec![1, 2, 3]);
        assert_eq!(r.id, 7);
        assert_eq!(r.num_tokens(), 3);
        assert_eq!(r.state, RequestState::Queued);
    }

    #[test]
    fn response_perplexity() {
        let resp = Response {
            id: 0,
            nll: vec![2f32.ln(); 4],
            generated: vec![],
            latency_ms: 1.0,
            kernel: "exact".into(),
            retained_keys: 8,
            realized_keys_mean: 8.0,
            realized_keys_p50: 8,
            realized_keys_p99: 8,
            fallback_used: false,
            decode_steps: 0,
            decode_ms: 0.0,
            degraded: false,
            spec: "exact".into(),
            error: None,
        };
        assert!((resp.perplexity() - 2.0).abs() < 1e-5);
        assert!(resp.is_ok());
    }

    #[test]
    fn deadline_helpers() {
        let r = Request::scoring(1, vec![1, 2]);
        assert_eq!(r.deadline(), None);
        assert!(!r.expired());
        let r = Request::scoring(2, vec![1, 2]).with_deadline(60_000);
        assert!(r.deadline().is_some());
        assert!(!r.expired(), "a minute-long deadline cannot have passed");
        let mut r = Request::scoring(3, vec![1, 2]).with_deadline(1);
        r.arrived = Instant::now() - Duration::from_millis(5);
        assert!(r.expired());
    }

    #[test]
    fn failure_response_is_typed() {
        let resp =
            Response::failure(9, 1.5, "exact".into(), ServerError::Capacity("full".into()));
        assert!(!resp.is_ok());
        assert_eq!(resp.error, Some(ServerError::Capacity("full".into())));
        assert!(resp.nll.is_empty() && resp.generated.is_empty());
        assert!(format!("{}", resp.error.unwrap()).contains("over capacity"));
    }
}
