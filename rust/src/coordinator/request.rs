//! Request/response types and lifecycle.

use std::time::Instant;

pub type RequestId = u64;

/// Lifecycle of a request inside the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting in the prefill queue.
    Queued,
    /// Selected into a prefill batch (pre-scoring runs here).
    Prefilling,
    /// In the decode loop (selection cached, refreshed periodically).
    Decoding,
    /// Finished; response delivered.
    Completed,
    /// Rejected/failed (e.g., over max_seq).
    Failed,
}

/// A scoring/generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Tokens to greedily generate after scoring (0 = scoring only).
    pub generate: usize,
    pub arrived: Instant,
    pub state: RequestState,
}

impl Request {
    pub fn scoring(id: RequestId, tokens: Vec<u32>) -> Self {
        Request { id, tokens, generate: 0, arrived: Instant::now(), state: RequestState::Queued }
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }
}

/// The response returned to the client.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// Per-token NLL over the scored context (length = context − 1).
    pub nll: Vec<f32>,
    /// Greedily generated continuation (empty for scoring-only).
    pub generated: Vec<u32>,
    /// Time-to-first-result in milliseconds.
    pub latency_ms: f64,
    /// Attention kernel that served this request (AttnStats::kernel).
    pub kernel: String,
    /// Keys the attention backend retained for this request's context
    /// (= context length when the backend is unfiltered or fell back).
    pub retained_keys: usize,
    /// Algorithm 2 line 2: the δ-fallback disabled filtering.
    pub fallback_used: bool,
    /// Tokens produced through the incremental decode path (0 for
    /// scoring-only requests served by the prefill/artifact path).
    pub decode_steps: usize,
    /// Total wall time spent inside decode steps for this request (ms) —
    /// per-step p50/p99 across requests lives in `ServerStats`.
    pub decode_ms: f64,
}

impl Response {
    /// Request-level perplexity.
    pub fn perplexity(&self) -> f64 {
        if self.nll.is_empty() {
            return f64::NAN;
        }
        (self.nll.iter().map(|&v| v as f64).sum::<f64>() / self.nll.len() as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::scoring(7, vec![1, 2, 3]);
        assert_eq!(r.id, 7);
        assert_eq!(r.num_tokens(), 3);
        assert_eq!(r.state, RequestState::Queued);
    }

    #[test]
    fn response_perplexity() {
        let resp = Response {
            id: 0,
            nll: vec![2f32.ln(); 4],
            generated: vec![],
            latency_ms: 1.0,
            kernel: "exact".into(),
            retained_keys: 8,
            fallback_used: false,
            decode_steps: 0,
            decode_ms: 0.0,
        };
        assert!((resp.perplexity() - 2.0).abs() < 1e-5);
    }
}
