//! Quantized KV storage: the numeric half of the tiered KV memory layer.
//!
//! Two representations, two jobs:
//!
//! * **Fake-quant mirrors** (live sessions) — the decode engine keeps its
//!   KV caches as plain f32 [`Matrix`] values, but under `[cache]
//!   kv_dtype = f16|int8` every K/V row is snapped onto the dtype's grid
//!   the moment it is produced (forward capture and each decode append).
//!   The attend micro-kernels — exact/flash/hyper/prescored, forward *and*
//!   decode arms — therefore consume exactly the values a dequantizing
//!   kernel would see, with zero hot-path format churn: the quantization
//!   error enters once, at row-production time, and forward/decode stay
//!   mutually consistent.
//!
//! * **[`QuantKv`] pages** (prefix-cache + disk tier) — cached KV rows are
//!   stored packed (f16 bits, or int8 codes with page-grouped per-row
//!   scales), charged to the `BlockAllocator` at the packed width: a
//!   16-token f32 page holds 32
//!   f16 or 64 int8 tokens, so an int8 cache pins ~4× the prompts in the
//!   same pool. Pages slice and concatenate **losslessly** (quantized bytes
//!   are moved, never re-quantized), which is what makes a disk-tier
//!   re-admit bitwise identical to the hot-RAM hit it replaces.
//!
//! The exactness contract under quantization relaxes from bitwise to a
//! pinned mean-relative ℓ2 bound vs f32 ([`KvDtype::l2_bound`]) plus a
//! PPL-delta gate on the Fig. 2 harness (`bench_kv_tier`).

use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// Rows per quantized page — matches the KV block size
/// ([`super::kv_cache::BLOCK_SIZE`]), so page scales align with allocator
/// pages.
pub const PAGE_ROWS: usize = super::kv_cache::BLOCK_SIZE;

/// Storage dtype for cached KV rows (`[cache] kv_dtype`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// Full precision — the bitwise-exact baseline.
    #[default]
    F32,
    /// IEEE-754 binary16, round-to-nearest-even. No scales needed.
    F16,
    /// Symmetric int8; each page carries its scale vector (one f32 scale
    /// per row, scale = row max_abs/127). Row-granular scales keep the
    /// ℓ2 bound under adversarial scale distributions — one outlier row
    /// cannot flatten its page-mates to zero — and make the cache grid
    /// identical to the live fake-quant grid.
    Int8,
}

impl KvDtype {
    pub fn parse(s: &str) -> Result<KvDtype> {
        match s.trim() {
            "f32" | "" => Ok(KvDtype::F32),
            "f16" => Ok(KvDtype::F16),
            "int8" => Ok(KvDtype::Int8),
            other => bail!("unknown kv_dtype '{other}' (expected f32 | f16 | int8)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }

    /// Packed bytes per stored element.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    /// Wire tag for the persist format (VERSION 5 spill sections).
    pub fn tag(self) -> u32 {
        match self {
            KvDtype::F32 => 0,
            KvDtype::F16 => 1,
            KvDtype::Int8 => 2,
        }
    }

    pub fn from_tag(tag: u32) -> Result<KvDtype> {
        match tag {
            0 => Ok(KvDtype::F32),
            1 => Ok(KvDtype::F16),
            2 => Ok(KvDtype::Int8),
            other => bail!("unknown kv dtype tag {other} (expected 0..=2)"),
        }
    }

    /// Tokens one allocator page holds at this dtype: the page's byte
    /// budget is fixed at `PAGE_ROWS` f32 tokens, so narrower dtypes pack
    /// proportionally more (f32: 16, f16: 32, int8: 64).
    pub fn tokens_per_page(self) -> usize {
        PAGE_ROWS * 4 / self.bytes_per_elem()
    }

    /// Pages charged for `tokens` cached tokens at this dtype.
    pub fn pages_for(self, tokens: usize) -> usize {
        tokens.div_ceil(self.tokens_per_page())
    }

    /// Pinned mean-relative ℓ2 bound vs f32 for values on this dtype's
    /// grid — the relaxed equivalence contract the property tests and the
    /// `bench_kv_tier` PPL gate enforce. f16 keeps 11 significand bits
    /// (≈ 2⁻¹¹ relative error per element); int8 rounds within half a step
    /// of a 127-level per-row grid, so a row's relative ℓ2 error is at
    /// most `√d·max_abs/(254·‖row‖) ≤ √d/254` (‖row‖ ≥ max_abs) — 0.025
    /// covers every head width the repo serves (d_head ≤ 32 ⇒ √d/254 ≤
    /// 0.0223), and the typical (Gaussian-row) error sits an order of
    /// magnitude below the pin.
    pub fn l2_bound(self) -> f32 {
        match self {
            KvDtype::F32 => 0.0,
            KvDtype::F16 => 1e-3,
            KvDtype::Int8 => 0.025,
        }
    }
}

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even (ties-to-even),
/// with overflow to ±inf and gradual underflow to subnormals.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (quiet bit forced so NaN survives the narrowing).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // Subnormal: shift the (implicit-bit) mantissa into place, RNE.
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && (half & 1) == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    // Normal: RNE on the 13 dropped mantissa bits. A mantissa carry
    // correctly overflows into the exponent (and to inf at the top).
    let half = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE-754 binary16 bits → f32 (exact: every f16 value is an f32 value).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: renormalize into an f32 normal.
            let mut e: u32 = 113; // f32 bias for 2^-14
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Snap one value onto the f16 grid (round-trip through binary16).
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Symmetric int8 scale for a slice: `max_abs / 127`, so the largest
/// magnitude maps to ±127 exactly and re-quantizing grid values is stable.
pub fn int8_scale(vals: &[f32]) -> f32 {
    let max_abs = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    max_abs / 127.0
}

#[inline]
fn int8_code(x: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// Fake-quantize one row in place onto the dtype's grid. Int8 uses a
/// per-row symmetric scale (the live-session grid); f16 is per-element;
/// f32 is the identity.
pub fn fake_quant_row(row: &mut [f32], dtype: KvDtype) {
    match dtype {
        KvDtype::F32 => {}
        KvDtype::F16 => {
            for v in row.iter_mut() {
                *v = f16_round(*v);
            }
        }
        KvDtype::Int8 => {
            let scale = int8_scale(row);
            for v in row.iter_mut() {
                *v = int8_code(*v, scale) as f32 * scale;
            }
        }
    }
}

/// Fake-quantize every row of a matrix in place (forward-capture path).
pub fn fake_quant_matrix(m: &mut Matrix, dtype: KvDtype) {
    if dtype == KvDtype::F32 {
        return;
    }
    for r in 0..m.rows {
        fake_quant_row(m.row_mut(r), dtype);
    }
}

/// One quantized page: up to [`PAGE_ROWS`] rows of packed values plus the
/// page's scale vector (one symmetric int8 scale per row; empty for f16,
/// whose grid is scale-free). Pages produced by slicing keep their
/// parent's scales and bytes — slicing never re-quantizes.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPage {
    pub scales: Vec<f32>,
    pub rows: usize,
    pub data: Vec<u8>,
}

/// A packed KV matrix: `rows × cols` values stored as a list of
/// [`QuantPage`]s. The page list is append-only in spirit — [`slice_rows`]
/// and [`concat`] move quantized bytes without touching the grids, so any
/// chain of slices and concats dequantizes bitwise-identically to the
/// original capture.
///
/// [`slice_rows`]: QuantKv::slice_rows
/// [`concat`]: QuantKv::concat
#[derive(Debug, Clone, PartialEq)]
pub struct QuantKv {
    pub dtype: KvDtype,
    pub cols: usize,
    pages: Vec<QuantPage>,
}

impl QuantKv {
    /// Pack an f32 matrix at `dtype`: page-grouped per-row scales + codes
    /// for int8, per-element bits for f16. `dtype` must not be
    /// [`KvDtype::F32`] — the full-precision representation is
    /// [`KvStore::F32`].
    pub fn quantize(m: &Matrix, dtype: KvDtype) -> QuantKv {
        assert!(dtype != KvDtype::F32, "QuantKv is for packed dtypes only");
        let mut pages = Vec::with_capacity(m.rows.div_ceil(PAGE_ROWS).max(1));
        let mut r0 = 0;
        while r0 < m.rows {
            let r1 = (r0 + PAGE_ROWS).min(m.rows);
            let page = match dtype {
                KvDtype::F16 => {
                    let vals = &m.data[r0 * m.cols..r1 * m.cols];
                    let mut data = Vec::with_capacity(vals.len() * 2);
                    for &v in vals {
                        data.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                    }
                    QuantPage { scales: Vec::new(), rows: r1 - r0, data }
                }
                KvDtype::Int8 => {
                    let mut scales = Vec::with_capacity(r1 - r0);
                    let mut data = Vec::with_capacity((r1 - r0) * m.cols);
                    for r in r0..r1 {
                        let row = m.row(r);
                        let scale = int8_scale(row);
                        scales.push(scale);
                        data.extend(row.iter().map(|&v| int8_code(v, scale) as u8));
                    }
                    QuantPage { scales, rows: r1 - r0, data }
                }
                KvDtype::F32 => unreachable!(),
            };
            pages.push(page);
            r0 = r1;
        }
        QuantKv { dtype, cols: m.cols, pages }
    }

    /// Unpack to f32. Deterministic: the same pages always dequantize to
    /// the same bits, which is the disk-tier re-admit guarantee.
    pub fn dequantize(&self) -> Matrix {
        let rows = self.rows();
        let mut out = Matrix::zeros(rows, self.cols);
        let mut r0 = 0;
        for page in &self.pages {
            let dst = &mut out.data[r0 * self.cols..(r0 + page.rows) * self.cols];
            match self.dtype {
                KvDtype::F16 => {
                    for (i, v) in dst.iter_mut().enumerate() {
                        let bits = u16::from_le_bytes([page.data[2 * i], page.data[2 * i + 1]]);
                        *v = f16_bits_to_f32(bits);
                    }
                }
                KvDtype::Int8 => {
                    for (i, v) in dst.iter_mut().enumerate() {
                        *v = (page.data[i] as i8) as f32 * page.scales[i / self.cols];
                    }
                }
                KvDtype::F32 => unreachable!(),
            }
            r0 += page.rows;
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.pages.iter().map(|p| p.rows).sum()
    }

    /// Packed payload bytes (tier accounting).
    pub fn byte_len(&self) -> usize {
        self.pages.iter().map(|p| p.data.len()).sum()
    }

    pub fn pages(&self) -> &[QuantPage] {
        &self.pages
    }

    /// Rebuild from decoded pages (persist load path), validating the
    /// byte-length and scale-count invariants per page.
    pub fn from_pages(dtype: KvDtype, cols: usize, pages: Vec<QuantPage>) -> Result<QuantKv> {
        for (i, p) in pages.iter().enumerate() {
            let want = p.rows * cols * dtype.bytes_per_elem();
            if p.data.len() != want {
                bail!(
                    "quant page {i}: {} payload bytes for {} rows × {} cols at {} \
                     (expected {want})",
                    p.data.len(),
                    p.rows,
                    cols,
                    dtype.as_str()
                );
            }
            let want_scales = if dtype == KvDtype::Int8 { p.rows } else { 0 };
            if p.scales.len() != want_scales {
                bail!(
                    "quant page {i}: {} scales for {} rows at {} (expected {want_scales})",
                    p.scales.len(),
                    p.rows,
                    dtype.as_str()
                );
            }
        }
        Ok(QuantKv { dtype, cols, pages })
    }

    /// Rows `[r0, r1)` as a new `QuantKv` — **lossless**: overlapping pages
    /// contribute their existing bytes and scale; partial overlaps become
    /// shorter pages on the same grid. No value is re-quantized.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> QuantKv {
        assert!(r0 <= r1 && r1 <= self.rows(), "slice_rows out of range");
        let elem = self.dtype.bytes_per_elem();
        let mut pages = Vec::new();
        let mut at = 0;
        for page in &self.pages {
            let (p0, p1) = (at, at + page.rows);
            at = p1;
            let lo = r0.max(p0);
            let hi = r1.min(p1);
            if lo >= hi {
                continue;
            }
            let b0 = (lo - p0) * self.cols * elem;
            let b1 = (hi - p0) * self.cols * elem;
            let scales = if page.scales.is_empty() {
                Vec::new()
            } else {
                page.scales[lo - p0..hi - p0].to_vec()
            };
            pages.push(QuantPage { scales, rows: hi - lo, data: page.data[b0..b1].to_vec() });
        }
        QuantKv { dtype: self.dtype, cols: self.cols, pages }
    }

    /// Append `other`'s rows — **lossless**: page lists concatenate, grids
    /// untouched. Panics on dtype/width mismatch (segments of one cached
    /// sequence always share both).
    pub fn concat(&self, other: &QuantKv) -> QuantKv {
        assert_eq!(self.dtype, other.dtype, "concat dtype mismatch");
        assert_eq!(self.cols, other.cols, "concat width mismatch");
        let mut pages = self.pages.clone();
        pages.extend(other.pages.iter().cloned());
        QuantKv { dtype: self.dtype, cols: self.cols, pages }
    }
}

/// A cached KV matrix at its storage dtype: full-precision f32, or packed
/// [`QuantKv`] pages. All prefix-cache segments hold one of these per K and
/// V; the f32 arm keeps the pre-quantization code path bitwise intact.
#[derive(Debug, Clone, PartialEq)]
pub enum KvStore {
    F32(Matrix),
    Quant(QuantKv),
}

impl KvStore {
    /// Pack a captured f32 matrix at the cache's configured dtype.
    pub fn from_matrix(m: Matrix, dtype: KvDtype) -> KvStore {
        match dtype {
            KvDtype::F32 => KvStore::F32(m),
            _ => KvStore::Quant(QuantKv::quantize(&m, dtype)),
        }
    }

    /// The f32 view the attend kernels consume (dequantize or clone).
    pub fn to_matrix(&self) -> Matrix {
        match self {
            KvStore::F32(m) => m.clone(),
            KvStore::Quant(q) => q.dequantize(),
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            KvStore::F32(_) => KvDtype::F32,
            KvStore::Quant(q) => q.dtype,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            KvStore::F32(m) => m.rows,
            KvStore::Quant(q) => q.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            KvStore::F32(m) => m.cols,
            KvStore::Quant(q) => q.cols,
        }
    }

    /// Stored payload bytes at the packed width.
    pub fn byte_len(&self) -> usize {
        match self {
            KvStore::F32(m) => m.data.len() * 4,
            KvStore::Quant(q) => q.byte_len(),
        }
    }

    /// Rows `[r0, r1)` — lossless under both representations.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> KvStore {
        match self {
            KvStore::F32(m) => KvStore::F32(m.slice_rows(r0, r1)),
            KvStore::Quant(q) => KvStore::Quant(q.slice_rows(r0, r1)),
        }
    }

    /// Append `other`'s rows — lossless; representations must match (one
    /// cached sequence is stored at one dtype end to end).
    pub fn concat(&self, other: &KvStore) -> KvStore {
        match (self, other) {
            (KvStore::F32(a), KvStore::F32(b)) => {
                assert_eq!(a.cols, b.cols, "concat width mismatch");
                let mut data = a.data.clone();
                data.extend_from_slice(&b.data);
                KvStore::F32(Matrix::from_vec(a.rows + b.rows, a.cols, data))
            }
            (KvStore::Quant(a), KvStore::Quant(b)) => KvStore::Quant(a.concat(b)),
            _ => panic!("concat across KV storage dtypes"),
        }
    }
}

/// Mean-relative ℓ2 error of `approx` vs `exact` over rows:
/// mean_r(‖a_r − e_r‖₂ / ‖e_r‖₂), skipping zero-norm reference rows. The
/// metric the relaxed equivalence contract pins.
pub fn mean_rel_l2(exact: &Matrix, approx: &Matrix) -> f32 {
    assert_eq!((exact.rows, exact.cols), (approx.rows, approx.cols));
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for r in 0..exact.rows {
        let (e, a) = (exact.row(r), approx.row(r));
        let norm: f32 = e.iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm == 0.0 {
            continue;
        }
        let diff: f32 =
            e.iter().zip(a).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
        sum += (diff / norm) as f64;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dtype_parse_roundtrip_and_accounting() {
        for d in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            assert_eq!(KvDtype::parse(d.as_str()).unwrap(), d);
            assert_eq!(KvDtype::from_tag(d.tag()).unwrap(), d);
        }
        assert!(KvDtype::parse("f64").is_err());
        assert!(KvDtype::from_tag(9).is_err());
        assert_eq!(KvDtype::F32.tokens_per_page(), 16);
        assert_eq!(KvDtype::F16.tokens_per_page(), 32);
        assert_eq!(KvDtype::Int8.tokens_per_page(), 64);
        assert_eq!(KvDtype::Int8.pages_for(65), 2);
        assert_eq!(KvDtype::F32.pages_for(65), 5);
    }

    #[test]
    fn f16_known_values_and_specials() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite f16
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00); // overflow → inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Smallest f16 subnormal is 2^-24; half of it rounds to zero (RNE).
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
    }

    #[test]
    fn f16_roundtrip_is_idempotent_over_all_bit_patterns() {
        // Every finite f16 value must survive f16→f32→f16 bit-identically
        // (the grid is a fixed point of the round-trip).
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            let mant = h & 0x3ff;
            if exp == 0x1f && mant != 0 {
                continue; // NaN payloads are canonicalized, not preserved
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "bits {h:#06x} drifted to {back:#06x}");
        }
    }

    #[test]
    fn fake_quant_is_stable_and_bounded() {
        let mut rng = Rng::new(7);
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let exact = Matrix::randn(48, 16, 1.0, &mut rng);
            let mut q = exact.clone();
            fake_quant_matrix(&mut q, dtype);
            let err = mean_rel_l2(&exact, &q);
            assert!(
                err > 0.0 && err < dtype.l2_bound(),
                "{}: mean-rel ℓ2 {err} vs bound {}",
                dtype.as_str(),
                dtype.l2_bound()
            );
            // The grid is (near-)fixed under re-quantization: f16 exactly;
            // int8 within fp rounding of the re-derived scale (≤ ~2 ulp).
            let mut again = q.clone();
            fake_quant_matrix(&mut again, dtype);
            if dtype == KvDtype::F16 {
                assert_eq!(again.data, q.data, "f16 grid is a fixed point");
            } else {
                assert!(mean_rel_l2(&q, &again) < 1e-6, "int8 grid drifted");
            }
        }
    }

    #[test]
    fn quantize_dequantize_roundtrip_meets_l2_bound() {
        let mut rng = Rng::new(11);
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let m = Matrix::randn(50, 16, 0.5, &mut rng);
            let q = QuantKv::quantize(&m, dtype);
            assert_eq!(q.rows(), 50);
            assert_eq!(q.pages().len(), 4); // 16+16+16+2
            assert_eq!(q.byte_len(), 50 * 16 * dtype.bytes_per_elem());
            let err = mean_rel_l2(&m, &q.dequantize());
            assert!(err < dtype.l2_bound(), "{}: {err}", dtype.as_str());
        }
    }

    #[test]
    fn int8_row_scales_map_row_max_to_exact_code() {
        let mut m = Matrix::zeros(3, 4);
        m.data = vec![0.5, -1.0, 0.25, 0.0, 4.0, -4.0, 2.0, 1.0, 0.1, 0.1, 0.1, 0.1];
        let q = QuantKv::quantize(&m, KvDtype::Int8);
        let page = &q.pages()[0];
        assert_eq!(page.scales.len(), 3, "one scale per row, grouped page-wise");
        assert!((page.scales[0] - 1.0 / 127.0).abs() < 1e-9);
        assert!((page.scales[1] - 4.0 / 127.0).abs() < 1e-9);
        // Each row's max-magnitude element lands on ±127 exactly.
        assert_eq!(page.data[1] as i8, -127);
        assert_eq!(page.data[4] as i8, 127);
        assert_eq!(page.data[5] as i8, -127);
        assert_eq!(page.data[8] as i8, 127);
        // A zero row has scale 0 and dequantizes to exact zeros.
        let z = QuantKv::quantize(&Matrix::zeros(2, 4), KvDtype::Int8);
        assert_eq!(z.pages()[0].scales, vec![0.0, 0.0]);
        assert!(z.dequantize().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn slice_and_concat_are_lossless_at_any_boundary() {
        let mut rng = Rng::new(3);
        for dtype in [KvDtype::F16, KvDtype::Int8] {
            let m = Matrix::randn(40, 8, 1.0, &mut rng);
            let q = QuantKv::quantize(&m, dtype);
            let full = q.dequantize();
            // Split at every row (page-aligned or not), re-join, compare
            // bitwise: slicing + concat never re-quantizes.
            for cut in 0..=40 {
                let head = q.slice_rows(0, cut);
                let tail = q.slice_rows(cut, 40);
                assert_eq!(head.rows(), cut);
                assert_eq!(tail.rows(), 40 - cut);
                let joined = head.concat(&tail);
                assert_eq!(
                    joined.dequantize().data,
                    full.data,
                    "{} cut {cut}: slice/concat drifted",
                    dtype.as_str()
                );
                // Slices of slices stay on the original grid too.
                if cut >= 10 {
                    let inner = head.slice_rows(3, cut.min(20));
                    assert_eq!(
                        inner.dequantize().data,
                        full.slice_rows(3, cut.min(20)).data
                    );
                }
            }
        }
    }

    #[test]
    fn kvstore_arms_agree_on_geometry_and_slicing() {
        let mut rng = Rng::new(5);
        let m = Matrix::randn(20, 8, 1.0, &mut rng);
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            let s = KvStore::from_matrix(m.clone(), dtype);
            assert_eq!(s.dtype(), dtype);
            assert_eq!((s.rows(), s.cols()), (20, 8));
            assert_eq!(s.byte_len(), 20 * 8 * dtype.bytes_per_elem());
            let a = s.slice_rows(5, 17);
            let b = s.slice_rows(0, 5).concat(&a);
            assert_eq!(
                b.concat(&s.slice_rows(17, 20)).to_matrix().data,
                s.to_matrix().data,
                "{}: KvStore slice/concat drifted",
                dtype.as_str()
            );
        }
        // f32 arm is bitwise the input.
        assert_eq!(KvStore::from_matrix(m.clone(), KvDtype::F32).to_matrix().data, m.data);
    }

    #[test]
    fn from_pages_validates_payload_lengths() {
        let m = Matrix::zeros(4, 4);
        let q = QuantKv::quantize(&m, KvDtype::Int8);
        let mut pages: Vec<QuantPage> = q.pages().to_vec();
        assert!(QuantKv::from_pages(KvDtype::Int8, 4, pages.clone()).is_ok());
        let mut truncated = pages.clone();
        truncated[0].data.pop();
        let err = QuantKv::from_pages(KvDtype::Int8, 4, truncated).unwrap_err();
        assert!(err.to_string().contains("payload bytes"), "{err}");
        pages[0].scales.pop();
        let err = QuantKv::from_pages(KvDtype::Int8, 4, pages).unwrap_err();
        assert!(err.to_string().contains("scales"), "{err}");
    }

    #[test]
    fn adversarial_scale_distributions_stay_within_bound() {
        // Pages mixing huge outliers with tiny rows are the worst case for
        // per-page int8 scales; the widened bound must still hold.
        let mut rng = Rng::new(13);
        let mut m = Matrix::randn(32, 8, 1e-3, &mut rng);
        for r in (0..32).step_by(7) {
            for v in m.row_mut(r).iter_mut() {
                *v *= 1e4; // outlier rows dominate their page's scale
            }
        }
        let q = QuantKv::quantize(&m, KvDtype::Int8);
        let err = mean_rel_l2(&m, &q.dequantize());
        assert!(err <= KvDtype::Int8.l2_bound(), "adversarial pages: {err}");
        // f16 is scale-free, so the same matrix stays near 2^-11.
        let qf = QuantKv::quantize(&m, KvDtype::F16);
        assert!(mean_rel_l2(&m, &qf.dequantize()) < KvDtype::F16.l2_bound());
    }
}
