//! Planted-subspace theory demo (§4): leverage separation (Thm 4.4),
//! k-means recovery (Thm 4.5), singleton case (Cor 4.6), ℓp generalization
//! (Claim 4.7), and the Appendix-B normalization counterexample.
//!
//! ```bash
//! cargo run --release --example planted_subspace
//! ```

use prescored::clustering::{kmeans_best_of, minkowski_kmeans, partitions_match};
use prescored::data::planted::{appendix_b_counterexample, generate, PlantedConfig};
use prescored::prescore::leverage::leverage_scores_exact;
use prescored::util::rng::Rng;

fn main() {
    let cfg = PlantedConfig { n: 600, d: 6, epsilon: 0.25, ..Default::default() };
    let inst = generate(&cfg);
    println!("planted model: n={} d={} m={} (ε={})", cfg.n, cfg.d, inst.m, cfg.epsilon);

    // Theorem 4.4: leverage separation.
    let h = leverage_scores_exact(&inst.matrix);
    let min_sig = inst.signal_rows.iter().map(|&i| h[i]).fold(f32::INFINITY, f32::min);
    let max_noise = (0..cfg.n)
        .filter(|&i| inst.labels[i] == 0)
        .map(|i| h[i])
        .fold(0.0f32, f32::max);
    println!("Thm 4.4  min signal leverage {min_sig:.4}  vs  max noise leverage {max_noise:.5}  (gap {:.1}×)", min_sig / max_noise.max(1e-9));

    // Theorem 4.5: k-means with k = d+1 recovers the planted partition.
    let mut rng = Rng::new(1);
    let c = kmeans_best_of(&inst.matrix, cfg.d + 1, 20, 5, &mut rng);
    println!("Thm 4.5  k-means recovers partition: {}", partitions_match(&c.assignment, &inst.labels));

    // Corollary 4.6: ε = 1 ⇒ singleton clusters per signal row.
    let cfg1 = PlantedConfig { n: 300, d: 5, epsilon: 1.0, c_s: 0.002, ..Default::default() };
    let inst1 = generate(&cfg1);
    let c1 = kmeans_best_of(&inst1.matrix, cfg1.d + 1, 20, 5, &mut rng);
    let sizes = c1.sizes();
    let singles = inst1.signal_rows.iter().filter(|&&i| sizes[c1.assignment[i]] == 1).count();
    println!("Cor 4.6  singleton signal clusters: {singles}/{}", inst1.signal_rows.len());

    // Claim 4.7: ℓp k-means recovery for p ∈ {1, 1.5, 3}.
    for p in [1.0f32, 1.5, 3.0] {
        let cp = minkowski_kmeans(&inst.matrix, cfg.d + 1, p, 20, &mut rng);
        println!("Claim 4.7  ℓ{p} k-means recovers: {}", partitions_match(&cp.assignment, &inst.labels));
    }

    // Appendix B: unnormalized failure vs normalized success.
    let (a, sig) = appendix_b_counterexample(80, 8, 50.0, 3);
    let raw = kmeans_best_of(&a, sig + 1, 20, 10, &mut rng);
    let raw_iso: std::collections::HashSet<_> = (0..sig).map(|i| raw.assignment[i]).collect();
    let mut an = a.clone();
    an.l2_normalize_rows(1e-12);
    let norm = kmeans_best_of(&an, sig + 1, 20, 10, &mut rng);
    let norm_iso: std::collections::HashSet<_> = (0..sig).map(|i| norm.assignment[i]).collect();
    println!(
        "App. B   unnormalized k-means isolates {}/{sig} signal rows; ℓ2-normalized isolates {}/{sig}",
        raw_iso.len(),
        norm_iso.len()
    );
}
