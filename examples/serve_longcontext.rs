//! END-TO-END DRIVER: the full three-layer stack on a real small workload.
//!
//! Loads the build-time-trained tiny LM (JAX → HLO text → PJRT), starts the
//! serving coordinator (router + dynamic batcher + executor thread), replays
//! a Poisson workload trace of long-context scoring requests against both
//! the exact and the pre-scored artifact, and reports
//! latency / throughput / perplexity. Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_longcontext
//! ```

use prescored::config::ServingConfig;
use prescored::coordinator::Request;
use prescored::data::{corpus, workload};
use prescored::metrics::PplAccum;
use prescored::server::ScoringServer;

fn run_variant(variant: &str, n_req: usize) -> anyhow::Result<()> {
    let cfg = ServingConfig {
        variant: variant.to_string(),
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    };
    let max_seq = cfg.max_seq;
    let server = ScoringServer::start(cfg)?;
    let trace = workload::generate_trace(&workload::WorkloadConfig {
        rate: 100.0,
        count: n_req,
        max_len: max_seq,
        long_frac: 0.3,
        seed: 42,
    });
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for req in &trace {
        let target = req.arrival_s / 5.0; // 5× compressed replay
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        let tokens = corpus::generate(512, req.context_len, req.corpus_seed);
        pending.push(server.submit(Request::scoring(req.id, tokens)));
    }
    let mut ppl = PplAccum::default();
    for rx in pending {
        ppl.add(&rx.recv()?.nll);
    }
    let stats = server.shutdown();
    println!(
        "{variant:<16} | {} req, {} batches | ppl {:8.3} | p50 {:7.1}ms  p99 {:7.1}ms | {:6.1} req/s | {:8.0} tok/s",
        stats.completed,
        stats.batches,
        ppl.ppl(),
        stats.latency_p50_ms,
        stats.latency_p99_ms,
        stats.throughput_rps,
        stats.tokens_per_s,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== E2E: serving long-context scoring requests through PJRT artifacts ==");
    let n_req = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    run_variant("exact", n_req)?;
    run_variant("prescored_k64", n_req)?;
    println!("\n(prescored_k64 restricts every attention layer to 64 pre-scored keys)");
    Ok(())
}
