//! END-TO-END DRIVER: the full three-layer stack on a real small workload.
//!
//! Three demos:
//!
//! 1. **Shared-prefix cache** (pure-Rust substrate, no artifacts needed):
//!    N requests over one long shared document prefix — the first request
//!    prefills cold and plants the prefix (KV pages + pre-score artifacts)
//!    in the radix tree; every later request walks the tree, branches
//!    copy-on-write off the cached node, and prefills only its own
//!    question suffix. Per-request latency and the server's prefix-cache
//!    hit/miss/evict accounting are printed.
//! 2. **Tiered KV memory under pressure**: cached pages stored as int8
//!    (`[cache] kv_dtype` — 4× the tokens per page) over a pool sized for
//!    roughly one document. Planting a second document evicts the first
//!    through the disk-spill tier (`[cache] spill_path`); asking about the
//!    first document again re-admits its subtree from disk — warm-disk,
//!    cheaper than a cold prefill — and `tier_spills` / `tier_readmits` /
//!    `tier_bytes` account for every hop.
//! 3. **Attention-mass key budget** (`mass=0.95`): every layer·head keeps
//!    the smallest score-order prefix covering 95% of its pre-score mass
//!    instead of one global top-k — the per layer·head realized budgets are
//!    printed from a direct prefill, then the same spec runs through the
//!    serving stack and the realized-budget telemetry (`realized_keys_*`,
//!    rung occupancy) is reported per response and in aggregate.
//! 4. **PJRT artifact replay** (requires `make artifacts`): the original
//!    Poisson long-context scoring trace against the exact and pre-scored
//!    artifacts.
//!
//! ```bash
//! cargo run --release --example serve_longcontext             # demos 1–3 (8k prefix)
//! cargo run --release --example serve_longcontext 4 2048      # 4 requests, 2k prefix
//! make artifacts && cargo run --release --example serve_longcontext  # all demos
//! cargo run --release --example serve_longcontext budget        # demo 3 only
//! cargo run --release --example serve_longcontext gateway 8080  # HTTP/SSE front door
//! ```
//!
//! **Gateway quickstart** (`gateway [port]` mode): the HTTP/SSE front door
//! from `prescored::gateway` on top of the same substrate server. Stream a
//! generation over Server-Sent Events with plain curl (`-N` disables
//! buffering so tokens render as they land):
//!
//! ```bash
//! # stream 16 tokens over a server-side 64-token synthetic context
//! curl -N -X POST http://127.0.0.1:8080/v1/generate \
//!      -H 'X-Pallas-Tenant: demo' \
//!      -d '{"corpus_len": 64, "generate": 16, "deadline_ms": 5000}'
//! # → event: token        (one per decode step, as it lands)
//! #   data: {"id":1,"tokens":[17],"total":1}
//! #   ...
//! #   event: done         (truthful served-spec / degraded / stats fields)
//! #   data: {"id":1,"generated":16,"spec":"prescored:...","degraded":false,...}
//!
//! # explicit token ids work too, and per-tenant quotas answer 429 +
//! # Retry-After when X-Pallas-Tenant exceeds its in-flight budget
//! curl -N -X POST http://127.0.0.1:8080/v1/generate -d '{"tokens": [1,2,3], "generate": 8}'
//!
//! # live stats: global terminal counters + per-tenant breakdown + the
//! # gateway admission ledger + session lifecycle counters
//! curl http://127.0.0.1:8080/v1/stats
//! # liveness / readiness probes (readyz flips to 503 while draining)
//! curl http://127.0.0.1:8080/healthz
//! curl http://127.0.0.1:8080/readyz
//! ```
//!
//! **Disconnect and resume.** Every stream is a server-issued session: the
//! response carries an `X-Pallas-Session` header and every `token` event an
//! `id: <session>:<seq>` cursor. Ctrl-C curl mid-stream — the session
//! *parks* (decode pauses, pages stay pinned) — then reconnect with the
//! last cursor you saw and the stream continues bitwise identically, no
//! recompute:
//!
//! ```bash
//! # first attempt: note the X-Pallas-Session response header and the id:
//! # lines on each event, then Ctrl-C after a few tokens
//! curl -Ni -X POST http://127.0.0.1:8080/v1/generate \
//!      -d '{"corpus_len": 64, "generate": 32}'
//! # → X-Pallas-Session: 1a2b3c4d5e6f7081-1
//! #   event: token
//! #   id: 1a2b3c4d5e6f7081-1:3
//! #   data: {"id":1,"tokens":[17],"total":3}
//! #   ^C
//!
//! # reconnect at the cursor: buffered tokens replay (marked
//! # "replayed":true), then the live stream continues to `done`
//! curl -N -X POST http://127.0.0.1:8080/v1/generate \
//!      -H 'Last-Event-ID: 1a2b3c4d5e6f7081-1:3'
//! ```
//!
//! Sessions nobody resumes are reclaimed after `session_linger_ms`
//! (`cancelled` ticks up in `/v1/stats`; pages release with balanced
//! accounting). A stale cursor that fell out of the bounded replay window
//! (`session_replay_tokens`) is refused with HTTP 410; an unknown session
//! with 404; a session another client still holds with 409.
//!
//! **Drain and restart.** Stop the process and the gateway drains: new
//! requests get 503 + `Retry-After` (and `/readyz` flips), in-flight
//! streams finish or park, and — when `[cache] persist_path` is set —
//! parked sessions are persisted alongside the prefix cache. A restarted
//! process on the same store re-registers them (`sessions_recovered` in
//! `/v1/stats`), and the same `Last-Event-ID` reconnect works across the
//! restart: the context re-admits under a fresh request id, prefills warm
//! from the restored cache (no second cold prefill), and greedy decode
//! fast-forwards below the high-water mark so the continuation stays
//! bitwise identical.
//!
//! **Fault-tolerance surface** (see ROADMAP.md "Failure model"): give a
//! request a wall-clock budget with `Request::with_deadline(ms)` (expired
//! requests fail typed as `DeadlineExceeded` at the next safe point), and
//! abandon one from any thread with `server.cancel(id)` — its KV pages and
//! prefix-cache pins are released, and the response carries
//! `ServerError::Cancelled` plus any partial tokens. Under pressure the
//! `[serving]` watermark keys (`shed_high_watermark` / `shed_low_watermark`
//! on KV-pool occupancy, `shed_queue_high` / `shed_queue_low` on prefill
//! queue depth) admit requests down a degradation ladder instead of
//! rejecting them; responses say so truthfully (`Response::degraded` + the
//! served spec string), and `shed_mode = "reject"` restores refusal
//! semantics. Every failure is a typed `Response::error`, never a dropped
//! channel.

use prescored::attention::{AttentionSpec, AttnPolicy};
use prescored::config::ServingConfig;
use prescored::coordinator::kv_cache::BLOCK_SIZE;
use prescored::coordinator::{KvDtype, Request};
use prescored::data::{corpus, workload};
use prescored::gateway::{Gateway, GatewayConfig};
use prescored::metrics::PplAccum;
use prescored::model::{Transformer, TransformerConfig};
use prescored::server::ScoringServer;

/// Demo 1: N requests sharing a long document prefix through the
/// shared-prefix cache.
fn run_prefix_demo(n_req: usize, prefix_tokens: usize) -> anyhow::Result<()> {
    let question_tokens = 64usize;
    let n_new = 16usize;
    let max_seq = prefix_tokens + question_tokens + n_new + 16;
    let tcfg = TransformerConfig {
        vocab: 512,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq,
    };
    let model = Transformer::random(tcfg, 7);
    let seq_pages = max_seq.div_ceil(BLOCK_SIZE) + 1;
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        max_seq,
        // flash is suffix-stable → partial warm hits; enough KV pages for a
        // few concurrent long sessions, and a prefix pool that holds the
        // document.
        attention_spec: "flash".into(),
        kv_blocks: seq_pages * 4,
        prefix_cache_blocks: seq_pages * 2,
        prefix_min_tokens: 64,
        decode_max_new: n_new,
        ..Default::default()
    };
    println!(
        "== shared-prefix cache: {n_req} requests over one {prefix_tokens}-token document =="
    );
    let server = ScoringServer::start_with_model(cfg, model)?;
    let document = corpus::generate(512, prefix_tokens, 1234);
    // Prime: one request over the bare document plants the prefix (KV pages
    // + per-layer·head artifacts) at an artifact boundary in the radix tree.
    let t0 = std::time::Instant::now();
    let mut prime = Request::scoring(0, document.clone());
    prime.generate = 1;
    server.submit(prime).recv()?;
    println!(
        "prime    : {prefix_tokens} prefill tokens | {:8.1} ms | cold (plants the prefix)",
        t0.elapsed().as_secs_f64() * 1e3
    );
    for i in 1..=n_req as u64 {
        let mut tokens = document.clone();
        tokens.extend_from_slice(&corpus::generate(512, question_tokens, 5000 + i));
        let mut req = Request::scoring(i, tokens);
        req.generate = n_new;
        let resp = server.submit(req).recv()?;
        println!(
            "request {i}: {} prefill tokens | {:8.1} ms | {} generated | warm \
             ({prefix_tokens} tokens from the cache, {question_tokens} prefilled)",
            prefix_tokens + question_tokens,
            resp.latency_ms,
            resp.generated.len(),
        );
    }
    let stats = server.shutdown();
    println!(
        "cache: {} hits / {} misses | {} prefill tokens served from cache | \
         {} insertions, {} evictions | {} nodes holding {} tokens",
        stats.prefix_hits,
        stats.prefix_misses,
        stats.prefix_hit_tokens,
        stats.prefix_insertions,
        stats.prefix_evictions,
        stats.prefix_nodes,
        stats.prefix_cached_tokens,
    );
    println!(
        "decode: {} steps, p50 {:.2} ms | prefills {}\n",
        stats.decode_steps, stats.decode_step_p50_ms, stats.prefills
    );
    Ok(())
}

/// Demo 2: memory pressure through the tiered KV cache — quantized pages,
/// disk spill on eviction, warm re-admit on the next radix hit.
fn run_tier_demo(prefix_tokens: usize) -> anyhow::Result<()> {
    let question_tokens = 64usize;
    let n_new = 8usize;
    let max_seq = prefix_tokens + question_tokens + n_new + 16;
    let tcfg = TransformerConfig {
        vocab: 512,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq,
    };
    let model = Transformer::random(tcfg, 7);
    let spill =
        std::env::temp_dir().join(format!("serve_longcontext_{}.spill", std::process::id()));
    // int8 pages pack 64 tokens instead of f32's 16, and the prefix pool
    // holds roughly ONE document chain — planting a second document forces
    // the first one out through the disk tier.
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        max_seq,
        attention_spec: "flash".into(),
        kv_blocks: max_seq.div_ceil(BLOCK_SIZE) * 4,
        prefix_cache_blocks: KvDtype::Int8.pages_for(max_seq) + 1,
        prefix_min_tokens: 64,
        decode_max_new: n_new,
        kv_dtype: "int8".into(),
        prefix_spill_path: spill.display().to_string(),
        ..Default::default()
    };
    println!(
        "== tiered KV memory: int8 pages, one-document pool, spill to {} ==",
        spill.display()
    );
    let server = ScoringServer::start_with_model(cfg, model)?;
    let doc_a = corpus::generate(512, prefix_tokens, 1234);
    let doc_b = corpus::generate(512, prefix_tokens, 4321);
    let ask = |id: u64, doc: &[u32], label: &str| -> anyhow::Result<f64> {
        let mut tokens = doc.to_vec();
        tokens.extend_from_slice(&corpus::generate(512, question_tokens, 9000 + id));
        let mut req = Request::scoring(id, tokens);
        req.generate = n_new;
        let resp = server.submit(req).recv()?;
        println!(
            "request {id}: doc {label} + question | {:8.1} ms | {} generated",
            resp.latency_ms,
            resp.generated.len()
        );
        Ok(resp.latency_ms)
    };
    // 1. Plant document A (cold prefill → quantized pages in RAM).
    let mut prime = Request::scoring(0, doc_a.clone());
    prime.generate = 1;
    let t0 = std::time::Instant::now();
    server.submit(prime).recv()?;
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("prime    : doc A planted cold      | {cold_ms:8.1} ms | (int8 pages, hot RAM)");
    // 2. Hot-RAM warm hit on A.
    let hot_ms = ask(1, &doc_a, "A (hot RAM)")?;
    // 3. Memory pressure: planting doc B evicts A's subtree → disk spill.
    let mut pressure = Request::scoring(2, doc_b.clone());
    pressure.generate = 1;
    server.submit(pressure).recv()?;
    println!("pressure : doc B planted — pool full, doc A spills to the disk tier");
    // 4. Ask about A again: radix miss in RAM, warm re-admit from disk.
    let warm_disk_ms = ask(3, &doc_a, "A (warm disk re-admit)")?;
    let stats = server.shutdown();
    let _ = std::fs::remove_file(&spill);
    println!(
        "tier: {} spills, {} re-admits, {} bytes through the spill file | \
         hot {:.1} ms vs warm-disk {:.1} ms (both beat the {:.1} ms cold prefill)\n",
        stats.tier_spills,
        stats.tier_readmits,
        stats.tier_bytes,
        hot_ms,
        warm_disk_ms,
        cold_ms,
    );
    Ok(())
}

/// Demo 3: the attention-mass key budget (`mass=0.95`) — every layer·head
/// keeps the smallest score-order prefix covering 95% of its pre-score
/// mass, so the realized budget *varies per head* instead of being one
/// global top-k. Prints the per layer·head realized selection sizes from a
/// direct prefill, then serves two requests of different lengths through
/// the substrate server and prints the realized-budget telemetry the
/// serving layer reports (`realized_keys_*` per response and in the
/// aggregate stats, plus shed-rung occupancy).
fn run_budget_demo() -> anyhow::Result<()> {
    let context = 256usize;
    let n_new = 8usize;
    let spec_str = "prescored:kmeans,mass=0.95,block=16,sample=4,mode=stream";
    let tcfg = TransformerConfig {
        vocab: 512,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq: context + n_new + 16,
    };
    let model = Transformer::random(tcfg.clone(), 7);
    println!("== attention-mass key budget: {spec_str} ==");
    // Direct prefill: read each layer·head's realized selection off the
    // decode-session states.
    let spec = AttentionSpec::parse(spec_str)?;
    let policy = AttnPolicy::uniform(spec);
    let tokens = corpus::generate(512, context, 1234);
    let (_, sess) = model.begin_decode(&tokens, &policy)?;
    let lens: Vec<usize> =
        sess.states().iter().filter_map(|s| s.selection().map(|sel| sel.len())).collect();
    for (i, chunk) in lens.chunks(tcfg.n_heads).enumerate() {
        let row: Vec<String> = chunk.iter().map(|k| format!("{k:>4}")).collect();
        println!("layer {i}: realized k per head = [{}] / {context} keys", row.join(", "));
    }
    let mean = lens.iter().sum::<usize>() as f64 / lens.len().max(1) as f64;
    println!(
        "mass=0.95 resolved to {:.1} keys on average (min {}, max {}) — the spread is \
         budget moved between peaked and flat heads",
        mean,
        lens.iter().min().copied().unwrap_or(0),
        lens.iter().max().copied().unwrap_or(0),
    );
    // Same spec through the serving stack: per-response and aggregate
    // realized-budget telemetry.
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        max_seq: context + n_new + 16,
        attention_spec: spec_str.into(),
        kv_blocks: (context + n_new).div_ceil(BLOCK_SIZE) * 4,
        decode_max_new: n_new,
        ..Default::default()
    };
    let server = ScoringServer::start_with_model(cfg, Transformer::random(tcfg, 7))?;
    for (id, len) in [(1u64, context), (2, context / 2)] {
        let mut req = Request::scoring(id, corpus::generate(512, len, 4000 + id));
        req.generate = n_new;
        let resp = server.submit(req).recv()?;
        println!(
            "request {id}: {len} ctx | {} generated | realized keys mean {:.1}, p50 {}, p99 {}",
            resp.generated.len(),
            resp.realized_keys_mean,
            resp.realized_keys_p50,
            resp.realized_keys_p99,
        );
    }
    let stats = server.shutdown();
    println!(
        "serving: realized keys mean {:.1}, p50 {:.0}, p99 {:.0} | rung occupancy {:?}\n",
        stats.realized_keys_mean,
        stats.realized_keys_p50,
        stats.realized_keys_p99,
        stats.rung_served,
    );
    Ok(())
}

/// `gateway [port]` mode: boot a substrate server behind the HTTP/SSE front
/// door and serve until killed. Pair it with the curl quickstart in the
/// module doc.
fn run_gateway(port: u16) -> anyhow::Result<()> {
    let max_seq = 4096;
    let tcfg = TransformerConfig {
        vocab: 512,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq,
    };
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        max_seq,
        attention_spec: "prescored:kmeans,top_k=64,block=16,sample=8".into(),
        executor_workers: 4,
        kv_blocks: max_seq.div_ceil(BLOCK_SIZE) * 8,
        ..Default::default()
    };
    let server = ScoringServer::start_with_model(cfg, Transformer::random(tcfg, 7))?;
    let gw_cfg = GatewayConfig {
        addr: format!("127.0.0.1:{port}"),
        max_in_flight_per_tenant: 16,
        max_generate: 256,
        corpus_vocab: 512,
        ..Default::default()
    };
    let gw = Gateway::start(gw_cfg, server)?;
    let addr = gw.addr();
    println!("== gateway: HTTP/SSE front door on http://{addr} ==");
    println!("stream a generation (SSE, one `token` event per decode round):");
    println!(
        "  curl -N -X POST http://{addr}/v1/generate \\\n       \
         -H 'X-Pallas-Tenant: demo' \\\n       \
         -d '{{\"corpus_len\": 64, \"generate\": 16, \"deadline_ms\": 5000}}'"
    );
    println!("inspect live serving stats / probes:");
    println!("  curl http://{addr}/v1/stats");
    println!("  curl http://{addr}/healthz   # liveness");
    println!("  curl http://{addr}/readyz    # 503 while draining");
    println!("resume an interrupted stream (Ctrl-C curl mid-stream, then):");
    println!(
        "  curl -N -X POST http://{addr}/v1/generate \\\n       \
         -H 'Last-Event-ID: <X-Pallas-Session header>:<last id: seq>'"
    );
    println!("Ctrl-C to stop.");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Demo 4: the original artifact replay (scoring trace via PJRT).
fn run_variant(variant: &str, n_req: usize) -> anyhow::Result<()> {
    let cfg = ServingConfig {
        variant: variant.to_string(),
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    };
    let max_seq = cfg.max_seq;
    let server = ScoringServer::start(cfg)?;
    let trace = workload::generate_trace(&workload::WorkloadConfig {
        rate: 100.0,
        count: n_req,
        max_len: max_seq,
        long_frac: 0.3,
        seed: 42,
    });
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for req in &trace {
        let target = req.arrival_s / 5.0; // 5× compressed replay
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        let tokens = corpus::generate(512, req.context_len, req.corpus_seed);
        pending.push(server.submit(Request::scoring(req.id, tokens)));
    }
    let mut ppl = PplAccum::default();
    for rx in pending {
        ppl.add(&rx.recv()?.nll);
    }
    let stats = server.shutdown();
    println!(
        "{variant:<16} | {} req, {} batches | ppl {:8.3} | p50 {:7.1}ms  p99 {:7.1}ms | {:6.1} req/s | {:8.0} tok/s",
        stats.completed,
        stats.batches,
        ppl.ppl(),
        stats.latency_p50_ms,
        stats.latency_p99_ms,
        stats.throughput_rps,
        stats.tokens_per_s,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if std::env::args().nth(1).as_deref() == Some("gateway") {
        let port = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(8080);
        return run_gateway(port);
    }
    if std::env::args().nth(1).as_deref() == Some("budget") {
        return run_budget_demo();
    }
    let n_req = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let prefix_tokens =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(8192);
    run_prefix_demo(n_req, prefix_tokens)?;
    run_tier_demo(prefix_tokens.min(1024))?;
    run_budget_demo()?;

    println!("== E2E: serving long-context scoring requests through PJRT artifacts ==");
    let replay_req = n_req.max(8) * 4;
    for variant in ["exact", "prescored_k64"] {
        if let Err(e) = run_variant(variant, replay_req) {
            println!("{variant:<16} | skipped ({e:#})");
        }
    }
    println!("\n(prescored_k64 restricts every attention layer to 64 pre-scored keys)");
    Ok(())
}
