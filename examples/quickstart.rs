//! Quickstart: pre-scored attention on random data, compared against exact.
//!
//! Kernels are constructed through the unified backend API: a declarative
//! spec string → [`AttentionSpec::parse`] → `.build()` →
//! [`prescored::attention::AttentionBackend::forward`], which returns the
//! output matrix plus unified stats (retained keys, fallback flag).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use prescored::attention::{exact_attention, rel_error, AttentionInputs, AttentionSpec};
use prescored::linalg::Matrix;
use prescored::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let (n, d) = (1024, 64);

    // Keys with a handful of globally-informative directions over a bulk
    // cloud — the geometry pre-scoring exploits.
    let mut k = Matrix::zeros(n, d);
    let base = 1.0 / (d as f32).sqrt();
    for i in 0..n {
        if i < 64 {
            let dir = i % 16;
            for j in 0..d {
                k[(i, j)] = rng.gauss32(if j == dir { 3.0 } else { 0.0 }, 0.02);
            }
        } else {
            for j in 0..d {
                k[(i, j)] = rng.gauss32(base, 0.05);
            }
        }
    }
    let mut q = Matrix::randn(n, d, 0.05, &mut rng);
    for i in 0..n {
        q[(i, i % 16)] += 4.0;
    }
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let inp = AttentionInputs::new(&q, &k, &v);

    let exact = exact_attention(&inp);
    println!("{:<24} {:>50} {:>11} {:>10}", "method", "spec", "rel-error", "keys");
    for (name, spec_str) in [
        ("kmeans+hyper (k=64)", "prescored:kmeans,top_k=64,pseed=1,sample=32,seed=1"),
        ("kmeans+hyper (k=128)", "prescored:kmeans,top_k=128,pseed=1,sample=32,seed=1"),
        ("leverage+hyper (k=64)", "prescored:leverage,top_k=64,pseed=1,sample=32,seed=1"),
        ("kmedian+hyper (k=64)", "prescored:kmedian,top_k=64,pseed=1,sample=32,seed=1"),
        ("unfiltered hyper", "prescored:kmeans,top_k=0,pseed=1,sample=32,seed=1"),
    ] {
        let backend = AttentionSpec::parse(spec_str).expect("valid spec").build();
        let r = backend.forward(&inp);
        println!(
            "{:<24} {:>50} {:>11.4} {:>7}/{}",
            name,
            spec_str,
            rel_error(&r.out, &exact),
            r.stats.retained_keys,
            r.stats.total_keys
        );
    }
    println!("\n(lower rel-error at the same key budget = better prioritization)");
}
