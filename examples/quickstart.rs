//! Quickstart: pre-scored attention on random data, compared against exact.
//!
//! Kernels are constructed through the unified backend API: a declarative
//! spec string → [`AttentionSpec::parse`] → `.build()` →
//! [`prescored::attention::AttentionBackend::forward`], which returns the
//! output matrix plus unified stats (retained keys, fallback flag).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use prescored::attention::{exact_attention, rel_error, AttentionInputs, AttentionSpec, AttnPolicy};
use prescored::data::corpus;
use prescored::linalg::Matrix;
use prescored::model::{Transformer, TransformerConfig};
use prescored::util::rng::Rng;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(0);
    let (n, d) = (1024, 64);

    // Keys with a handful of globally-informative directions over a bulk
    // cloud — the geometry pre-scoring exploits.
    let mut k = Matrix::zeros(n, d);
    let base = 1.0 / (d as f32).sqrt();
    for i in 0..n {
        if i < 64 {
            let dir = i % 16;
            for j in 0..d {
                k[(i, j)] = rng.gauss32(if j == dir { 3.0 } else { 0.0 }, 0.02);
            }
        } else {
            for j in 0..d {
                k[(i, j)] = rng.gauss32(base, 0.05);
            }
        }
    }
    let mut q = Matrix::randn(n, d, 0.05, &mut rng);
    for i in 0..n {
        q[(i, i % 16)] += 4.0;
    }
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let inp = AttentionInputs::new(&q, &k, &v);

    let exact = exact_attention(&inp);
    println!("{:<24} {:>50} {:>11} {:>10}", "method", "spec", "rel-error", "keys");
    for (name, spec_str) in [
        ("kmeans+hyper (k=64)", "prescored:kmeans,top_k=64,pseed=1,sample=32,seed=1"),
        ("kmeans+hyper (k=128)", "prescored:kmeans,top_k=128,pseed=1,sample=32,seed=1"),
        ("leverage+hyper (k=64)", "prescored:leverage,top_k=64,pseed=1,sample=32,seed=1"),
        ("kmedian+hyper (k=64)", "prescored:kmedian,top_k=64,pseed=1,sample=32,seed=1"),
        ("unfiltered hyper", "prescored:kmeans,top_k=0,pseed=1,sample=32,seed=1"),
    ] {
        let backend = AttentionSpec::parse(spec_str).expect("valid spec").build();
        let r = backend.forward(&inp);
        println!(
            "{:<24} {:>50} {:>11.4} {:>7}/{}",
            name,
            spec_str,
            rel_error(&r.out, &exact),
            r.stats.retained_keys,
            r.stats.total_keys
        );
    }
    println!("\n(lower rel-error at the same key budget = better prioritization)");

    decode_demo();
}

/// The serving fast path in miniature: prefill once, then stream tokens
/// through each backend's incremental `decode_step` (KV caches + cached
/// selections advance one row per token — prefill is never re-run).
fn decode_demo() {
    let cfg =
        TransformerConfig { vocab: 128, d_model: 64, n_layers: 2, n_heads: 4, max_seq: 256 };
    let model = Transformer::random(cfg, 7);
    let prompt = corpus::generate(128, 192, 11);
    let n_new = 32usize;

    println!("\n== decode loop: prefill {} tokens once, stream {n_new} ==", prompt.len());
    println!("{:<52} {:>12} {:>14}", "spec", "tokens/sec", "per-step ms");
    for spec_str in [
        "exact",
        "flash",
        "prescored:kmeans,top_k=48,refresh=16,block=32",
        "restricted:l2norm,top_k=48",
    ] {
        let policy = AttnPolicy::parse(spec_str).expect("valid spec");
        let (logits, mut sess) =
            model.begin_decode(&prompt, &policy).expect("spec has a decode arm");
        let mut next = prescored::model::transformer::argmax_row(logits.row(logits.rows - 1));
        let t0 = Instant::now();
        for _ in 0..n_new {
            let row = model.decode_token(&mut sess, next, &policy);
            next = prescored::model::transformer::argmax_row(&row);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<52} {:>12.1} {:>14.3}",
            spec_str,
            n_new as f64 / dt,
            dt * 1e3 / n_new as f64
        );
    }
    println!("(selection-restricted specs pay |S|-sized work per step, not context-sized)");
}
