//! Quickstart: pre-scored attention on random data, compared against exact.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use prescored::attention::{
    exact_attention, prescored_hyper_attention, rel_error, AttentionInputs, Coupling, HyperConfig,
    PreScoredConfig,
};
use prescored::linalg::Matrix;
use prescored::prescore::{Method, PreScoreConfig};
use prescored::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let (n, d) = (1024, 64);

    // Keys with a handful of globally-informative directions over a bulk
    // cloud — the geometry pre-scoring exploits.
    let mut k = Matrix::zeros(n, d);
    let base = 1.0 / (d as f32).sqrt();
    for i in 0..n {
        if i < 64 {
            let dir = i % 16;
            for j in 0..d {
                k[(i, j)] = rng.gauss32(if j == dir { 3.0 } else { 0.0 }, 0.02);
            }
        } else {
            for j in 0..d {
                k[(i, j)] = rng.gauss32(base, 0.05);
            }
        }
    }
    let mut q = Matrix::randn(n, d, 0.05, &mut rng);
    for i in 0..n {
        q[(i, i % 16)] += 4.0;
    }
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let inp = AttentionInputs::new(&q, &k, &v);

    let exact = exact_attention(&inp);
    println!("{:<28} {:>12} {:>10}", "method", "rel-error", "keys");
    for (name, top_k, method) in [
        ("kmeans+hyper (k=64)", 64usize, Method::KMeans),
        ("kmeans+hyper (k=128)", 128, Method::KMeans),
        ("leverage+hyper (k=64)", 64, Method::Leverage { exact: false }),
        ("kmedian+hyper (k=64)", 64, Method::KMedian),
        ("unfiltered hyper", 0, Method::KMeans),
    ] {
        let cfg = PreScoredConfig {
            prescore: PreScoreConfig { method, top_k, seed: 1, ..Default::default() },
            hyper: HyperConfig { block_size: 64, sample_size: 32, seed: 1, ..Default::default() },
            fallback_delta: 0.0,
            coupling: Coupling::Glm3Corrected,
        };
        let (out, stats) = prescored_hyper_attention(&inp, &cfg);
        println!(
            "{:<28} {:>12.4} {:>7}/{}",
            name,
            rel_error(&out, &exact),
            stats.selected,
            stats.total_keys
        );
    }
    println!("\n(lower rel-error at the same key budget = better prioritization)");
}
