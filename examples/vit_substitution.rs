//! Zero-shot attention substitution in a trained ViT (§5.3 demo).
//!
//! Loads the build-time-trained ViT (artifacts/vit_weights.bin) and replaces
//! its softmax attention with K-means-sampled restricted attention at a few
//! budgets, reporting retained accuracy.
//!
//! ```bash
//! make artifacts && cargo run --release --example vit_substitution
//! ```

use prescored::data::images::ImageConfig;
use prescored::exp::{vit_accuracy, vit_eval_data};
use prescored::model::{Vit, VitAttnMode, VitConfig, WeightStore};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let weights = Path::new("artifacts/vit_weights.bin");
    if !weights.exists() {
        eprintln!("vit_weights.bin missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let ws = WeightStore::load(weights)?;
    let vit = Vit::from_weights(&ws, VitConfig::default());
    let img_cfg = ImageConfig::default();
    let data = vit_eval_data(&img_cfg, 200, 9);

    println!("{:<40} {:>10}", "configuration", "top-1 acc");
    let base = vit_accuracy(&vit, &data, &VitAttnMode::Exact);
    println!("{:<40} {:>9.2}%", "base model (softmax attention)", base * 100.0);
    for (clusters, samples) in [(4usize, 8usize), (4, 16), (4, 32), (6, 32)] {
        let acc = vit_accuracy(
            &vit,
            &data,
            &VitAttnMode::KMeansSampled { num_clusters: clusters, num_samples: samples, seed: 1 },
        );
        println!(
            "{:<40} {:>9.2}%",
            format!("kmeans num_cluster={clusters}, num_sample={samples}"),
            acc * 100.0
        );
    }
    for k in [16usize, 32] {
        let acc = vit_accuracy(&vit, &data, &VitAttnMode::LeverageTopK { k, exact: true });
        println!("{:<40} {:>9.2}%", format!("leverage top-{k}"), acc * 100.0);
    }
    Ok(())
}
