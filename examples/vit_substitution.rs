//! Zero-shot attention substitution in a trained ViT (§5.3 demo).
//!
//! Loads the build-time-trained ViT (artifacts/vit_weights.bin) and replaces
//! its softmax attention with K-means-sampled restricted attention at a few
//! budgets, reporting retained accuracy. Each configuration is a declarative
//! attention spec string (`restricted:...`) built through the unified
//! backend registry.
//!
//! ```bash
//! make artifacts && cargo run --release --example vit_substitution
//! ```

use prescored::attention::AttentionSpec;
use prescored::data::images::ImageConfig;
use prescored::exp::{vit_accuracy, vit_eval_data};
use prescored::model::{Vit, VitConfig, WeightStore};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let weights = Path::new("artifacts/vit_weights.bin");
    if !weights.exists() {
        eprintln!("vit_weights.bin missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let ws = WeightStore::load(weights)?;
    let vit = Vit::from_weights(&ws, VitConfig::default());
    let img_cfg = ImageConfig::default();
    let data = vit_eval_data(&img_cfg, 200, 9);

    // The sweep: spec strings, parsed through the single construction path.
    let mut sweep: Vec<(String, String)> =
        vec![("base model (softmax attention)".into(), "exact".into())];
    for (clusters, samples) in [(4usize, 8usize), (4, 16), (4, 32), (6, 32)] {
        sweep.push((
            format!("kmeans num_cluster={clusters}, num_sample={samples}"),
            format!("restricted:balanced,clusters={clusters},samples={samples},seed=1"),
        ));
    }
    for k in [16usize, 32] {
        sweep.push((
            format!("leverage top-{k}"),
            format!("restricted:leverage-exact,top_k={k}"),
        ));
    }

    println!("{:<40} {:>10}", "configuration", "top-1 acc");
    for (label, spec_str) in &sweep {
        let spec = AttentionSpec::parse(spec_str)?;
        let acc = vit_accuracy(&vit, &data, &spec);
        println!("{label:<40} {:>9.2}%", acc * 100.0);
    }
    Ok(())
}
