#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 verify (build + tests).
#
# Usage: ./ci.sh [--quick]
#   --quick   skip fmt/clippy, run tier-1 only
set -euo pipefail
cd "$(dirname "$0")"

QUICK=0
[[ "${1:-}" == "--quick" ]] && QUICK=1

if [[ "$QUICK" == 0 ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --all -- --check
    else
        echo "== cargo fmt unavailable — skipping format check =="
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy -D warnings =="
        cargo clippy --workspace --all-targets -- -D warnings
    else
        echo "== cargo clippy unavailable — skipping lint =="
    fi
    # Examples and benches are not exercised by `cargo test`; build them so
    # dispatch-surface refactors can't silently break non-test targets.
    echo "== cargo build --release --examples --benches =="
    cargo build --release --examples --benches

    # Decode-path smoke: tiny env-gated sizes so the incremental decode
    # engine and its JSON emitter can't silently rot. The real baseline
    # (BENCH_decode.json) comes from running the bench without the knobs;
    # the smoke output goes to a scratch file so it never clobbers one.
    echo "== bench_decode_throughput (smoke) =="
    PALLAS_DECODE_CONTEXTS=256,512 PALLAS_DECODE_STEPS=4 PALLAS_DECODE_D=32 \
    PALLAS_DECODE_JSON="$(mktemp)" \
        cargo bench --bench bench_decode_throughput
fi

echo "== tier-1 verify: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "CI OK"
