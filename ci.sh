#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 verify (build + tests).
#
# Usage: ./ci.sh [--quick]
#   --quick   skip fmt/clippy, run tier-1 only
set -euo pipefail
cd "$(dirname "$0")"

QUICK=0
[[ "${1:-}" == "--quick" ]] && QUICK=1

# Unwrap/expect lint gate for the serving + cache hot paths: every
# `.unwrap()` / `.expect(` outside `#[cfg(test)]` must carry a trailing
# `// unwrap-ok: <reason>` marker, or the panic it hides belongs in the
# typed ServerError surface instead.
echo "== unwrap/expect gate (rust/src/server, rust/src/cache, rust/src/gateway) =="
if ! awk '
    FNR == 1 { in_tests = 0 }
    /#\[cfg\(test\)\]/ { in_tests = 1 }
    in_tests { next }
    (/\.unwrap\(\)/ || /\.expect\(/) && !/unwrap-ok/ {
        printf "%s:%d: unmarked unwrap/expect on a serving hot path:\n    %s\n", FILENAME, FNR, $0
        bad = 1
    }
    END { exit bad }
' rust/src/server/*.rs rust/src/cache/*.rs rust/src/gateway/*.rs; then
    echo "unwrap/expect gate FAILED — convert to a typed error or mark '// unwrap-ok: <reason>'"
    exit 1
fi

if [[ "$QUICK" == 0 ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --all -- --check
    else
        echo "== cargo fmt unavailable — skipping format check =="
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy -D warnings =="
        cargo clippy --workspace --all-targets -- -D warnings
    else
        echo "== cargo clippy unavailable — skipping lint =="
    fi
    # Examples and benches are not exercised by `cargo test`; build them so
    # dispatch-surface refactors can't silently break non-test targets.
    echo "== cargo build --release --examples --benches =="
    cargo build --release --examples --benches

    # Decode-path smoke: tiny env-gated sizes so the incremental decode
    # engine and its JSON emitter can't silently rot. The real baseline
    # (BENCH_decode.json) comes from running the bench without the knobs;
    # the smoke output goes to a scratch file so it never clobbers one.
    echo "== bench_decode_throughput (smoke) =="
    PALLAS_DECODE_CONTEXTS=256,512 PALLAS_DECODE_STEPS=4 PALLAS_DECODE_D=32 \
    PALLAS_DECODE_JSON="$(mktemp)" \
        cargo bench --bench bench_decode_throughput

    # Prefix-cache smoke: env-shrunk cold-vs-warm prefill on a shared-prefix
    # workload. PALLAS_PREFIX_ASSERT=1 makes the bench exit non-zero if the
    # warm hit does not beat the cold prefill at the largest shared
    # fraction — the cache's reason to exist is a CI invariant.
    echo "== bench_prefix_cache (smoke) =="
    PALLAS_PREFIX_CONTEXT=256 PALLAS_PREFIX_D=32 PALLAS_PREFIX_REPS=3 \
    PALLAS_PREFIX_FRACS=0.5,0.9 PALLAS_PREFIX_ASSERT=1 \
    PALLAS_PREFIX_JSON="$(mktemp)" \
        cargo bench --bench bench_prefix_cache

    # Streaming pre-scoring smoke: env-shrunk refresh-cost A/B (full
    # re-cluster vs stream fold+merge) + stream-spec warm prefill.
    # PALLAS_STREAM_ASSERT=1 fails the build if a stream refresh ever stops
    # beating the full re-cluster — the O(|new|·k) refresh contract is a CI
    # invariant.
    echo "== bench_stream_prescore (smoke) =="
    PALLAS_STREAM_CONTEXTS=512,2048 PALLAS_STREAM_D=32 PALLAS_STREAM_TOPK=32 \
    PALLAS_STREAM_REPS=3 PALLAS_STREAM_WARM_CONTEXT=256 PALLAS_STREAM_ASSERT=1 \
    PALLAS_STREAM_JSON="$(mktemp)" \
        cargo bench --bench bench_stream_prescore

    # Degrade-vs-reject smoke: env-shrunk ladder sweep under a starved KV
    # pool. PALLAS_SHED_ASSERT=1 fails the build if any ladder rung ever
    # completes fewer tokens than refusing the overflow outright — the
    # degrade-don't-reject contract is a CI invariant.
    echo "== bench_shed_quality (smoke) =="
    PALLAS_SHED_REQUESTS=8 PALLAS_SHED_CONTEXT=32 PALLAS_SHED_NEW=8 \
    PALLAS_SHED_ASSERT=1 PALLAS_SHED_JSON="$(mktemp)" \
        cargo bench --bench bench_shed_quality

    # Gateway wire smoke: boot the HTTP/SSE front door on an ephemeral
    # port, stream one generation over a real TCP socket, and assert >= 1
    # SSE token event plus a clean `done` terminal — the wire path from
    # POST to cancel-safe stream teardown is a CI invariant.
    echo "== gateway wire smoke =="
    cargo test --release --test gateway \
        sse_stream_delivers_tokens_incrementally_and_done -- --nocapture

    # Gateway streaming smoke: env-shrunk concurrency sweep.
    # PALLAS_GATEWAY_ASSERT=1 fails the build if aggregate streamed
    # throughput collapses as clients pile on — continuous batching is a CI
    # invariant.
    echo "== bench_gateway (smoke) =="
    PALLAS_GATEWAY_CLIENTS=1,4 PALLAS_GATEWAY_CONTEXT=24 PALLAS_GATEWAY_NEW=4 \
    PALLAS_GATEWAY_ASSERT=1 PALLAS_GATEWAY_JSON="$(mktemp)" \
        cargo bench --bench bench_gateway

    # Resume wire smoke: disconnect mid-stream at every cut point and
    # reconnect with Last-Event-ID — the combined stream must be bitwise
    # identical to the uninterrupted reference.
    echo "== resume wire smoke =="
    cargo test --release --test resume \
        resume_at_every_cut_is_bitwise_identical -- --nocapture

    # Resume-vs-cold smoke: env-shrunk interrupted-stream completion.
    # PALLAS_RESUME_ASSERT=1 fails the build if resuming a parked session
    # ever stops beating a cold recompute — O(remaining decode) resumption
    # is a CI invariant.
    echo "== bench_resume (smoke) =="
    PALLAS_RESUME_CONTEXT=96 PALLAS_RESUME_NEW=8 PALLAS_RESUME_REPS=3 \
    PALLAS_RESUME_ASSERT=1 PALLAS_RESUME_JSON="$(mktemp)" \
        cargo bench --bench bench_resume

    # Tiered-KV smoke: env-shrunk capacity × dtype sweep plus warm-disk
    # vs cold latency. PALLAS_TIER_ASSERT=1 fails the build if int8 stops
    # caching >= 2x the f32 tokens at an equal page pool, if a warm-disk
    # re-admit stops beating a cold prefill, or if the quantized NLL
    # deltas drift past their pinned budgets.
    echo "== bench_kv_tier (smoke) =="
    PALLAS_TIER_CONTEXT=128 PALLAS_TIER_PROMPTS=12 PALLAS_TIER_REPS=3 \
    PALLAS_TIER_ASSERT=1 PALLAS_TIER_JSON="$(mktemp)" \
        cargo bench --bench bench_kv_tier

    # Key-budget smoke: env-shrunk mass-vs-fixed PPL comparison at equal
    # average realized budget. PALLAS_BUDGET_ASSERT=1 fails the build if the
    # attention-mass policy ever loses to the matched fixed top-k — adaptive
    # per-head allocation paying for itself is a CI invariant.
    echo "== bench_budget (smoke) =="
    PALLAS_BUDGET_DOCS=2 PALLAS_BUDGET_CONTEXT=96 PALLAS_BUDGET_SAMPLE=4 \
    PALLAS_BUDGET_MASS=0.7,0.9 PALLAS_BUDGET_ASSERT=1 \
    PALLAS_BUDGET_JSON="$(mktemp)" \
        cargo bench --bench bench_budget

    # Chaos smoke: three fixed seeded fault schedules through the mixed
    # scoring + generation workload. The suite asserts no process panic,
    # a typed response per request, and balanced page/pin accounting.
    echo "== fault-injection chaos smoke (seeds 101 202 303) =="
    for seed in 101 202 303; do
        echo "-- chaos seed $seed --"
        PALLAS_FAULT_PLAN=chaos PALLAS_FAULT_SEED=$seed \
            cargo test --release --test fault_injection chaos_env_schedule -- --nocapture
    done
fi

echo "== tier-1 verify: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "CI OK"
