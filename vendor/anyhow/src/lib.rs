//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so this path dependency
//! provides the exact subset the workspace uses: [`Error`] (a context-chain
//! error), [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Semantics mirror
//! real `anyhow` where it matters here:
//!
//! * `Display` shows the outermost message; the alternate form (`{:#}`)
//!   shows the whole chain joined by `": "`, which is what the launcher and
//!   server log lines rely on.
//! * Any `std::error::Error` converts into [`Error`] via `?`, capturing its
//!   `source()` chain.

use std::fmt;

/// A context-chain error: `chain[0]` is the outermost (most recent) context,
/// the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a root message.
    pub fn new(msg: String) -> Error {
        Error { chain: vec![msg] }
    }

    /// Alias of [`Error::new`] taking anything displayable (parity with
    /// `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error::new(m.to_string())
    }

    /// Wrap with an outer context message.
    pub fn context_msg(mut self, msg: String) -> Error {
        self.chain.insert(0, msg);
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `context` / `with_context` to `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context_msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context_msg(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = anyhow!("root {}", 7);
        let e = e.context_msg("outer".into());
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 7");
        assert_eq!(format!("{e:?}"), "outer: root 7");
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner() -> Result<()> {
            bail!("nope: {}", 42);
        }
        fn outer() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r.context("while reading")?;
            Ok(())
        }
        assert_eq!(format!("{:#}", inner().unwrap_err()), "nope: 42");
        assert_eq!(format!("{:#}", outer().unwrap_err()), "while reading: missing thing");
    }

    #[test]
    fn context_on_option_and_results() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("empty").unwrap_err()), "empty");
        let ok: Option<u32> = Some(5);
        assert_eq!(ok.context("unused").unwrap(), 5);
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("ctx {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx 1: missing thing");
    }

    #[test]
    fn error_chains_compose() {
        fn level1() -> Result<()> {
            bail!("root cause");
        }
        fn level2() -> Result<()> {
            level1().context("level2")?;
            Ok(())
        }
        let e = level2().unwrap_err();
        assert_eq!(e.root_cause(), "root cause");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["level2", "root cause"]);
    }
}
