"""Pallas kernel vs pure-jnp oracle — the CORE Layer-1 correctness signal.

hypothesis sweeps shapes/blocks; fixed cases cover the masking edge cases
(fully-masked rows, padding tiles, singleton keys).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kmeans import kmeans_lloyd, pairwise_sq_dists_pallas
from compile.kernels.prescored_attn import (
    selected_attention_heads,
    selected_attention_pallas,
)

RNG = np.random.default_rng(0)


def _mk(n, s, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    kpos = jnp.sort(jnp.asarray(rng.choice(max(n, s), size=s, replace=False), jnp.int32))
    return q, k, v, kpos


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 96),
    s=st.integers(1, 64),
    d=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([4, 16, 128]),
    bk=st.sampled_from([2, 8, 128]),
    causal=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_selected_attention_matches_ref_hypothesis(n, s, d, bq, bk, causal, seed):
    q, k, v, kpos = _mk(n, s, d, seed)
    out = selected_attention_pallas(q, k, v, kpos, causal=causal, block_q=bq, block_k=bk)
    want = ref.selected_attention(q, k, v, kpos, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("n,s,d", [(1, 1, 4), (7, 3, 8), (128, 128, 32), (33, 17, 8)])
def test_selected_attention_fixed_cases(n, s, d, causal):
    q, k, v, kpos = _mk(n, s, d, seed=n * 100 + s)
    out = selected_attention_pallas(q, k, v, kpos, causal=causal, block_q=16, block_k=8)
    want = ref.selected_attention(q, k, v, kpos, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_fully_masked_rows_are_zero():
    # All selected keys at positions AFTER every query → causal masks all.
    n, s, d = 6, 4, 8
    q, k, v, _ = _mk(n, s, d, seed=5)
    kpos = jnp.asarray([10, 11, 12, 13], jnp.int32)
    out = selected_attention_pallas(q, k, v, kpos, causal=True, block_q=4, block_k=2)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_anchor_position_zero_always_attended():
    n, s, d = 16, 3, 8
    q, k, v, _ = _mk(n, s, d, seed=6)
    kpos = jnp.asarray([0, 9, 12], jnp.int32)
    out = selected_attention_pallas(q, k, v, kpos, causal=True, block_q=8, block_k=2)
    # Query 0 can only see key at position 0 → output = v[0].
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0]), atol=1e-5)


def test_heads_vmap_matches_per_head():
    H, n, s, d = 3, 24, 9, 8
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(H, n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(H, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(H, s, d)), jnp.float32)
    kpos = jnp.stack(
        [jnp.sort(jnp.asarray(rng.choice(n, s, replace=False), jnp.int32)) for _ in range(H)]
    )
    out = selected_attention_heads(q, k, v, kpos, causal=True)
    want = jnp.stack([ref.selected_attention(q[h], k[h], v[h], kpos[h]) for h in range(H)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 200),
    d=st.sampled_from([2, 8, 16]),
    k=st.integers(1, 9),
    bn=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 1000),
)
def test_pairwise_dists_kernel_hypothesis(n, d, k, bn, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    got = pairwise_sq_dists_pallas(x, c, block_n=bn)
    _, want = ref.kmeans_assign(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_kmeans_lloyd_recovers_blobs():
    rng = np.random.default_rng(1)
    a = rng.normal(size=(40, 4)) * 0.1 + np.array([3, 0, 0, 0])
    b = rng.normal(size=(40, 4)) * 0.1 - np.array([3, 0, 0, 0])
    x = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    _, assign, d2 = kmeans_lloyd(x, k=2, iters=8)
    assign = np.asarray(assign)
    assert len(set(assign[:40])) == 1
    assert len(set(assign[40:])) == 1
    assert assign[0] != assign[40]
    assert float(jnp.max(d2)) < 0.5


def test_kmeans_lloyd_distances_nonnegative():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    _, _, d2 = kmeans_lloyd(x, k=9, iters=4)
    assert float(jnp.min(d2)) > -1e-4
