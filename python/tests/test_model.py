"""L2 model tests: shapes, loss behaviour, prescored-vs-exact consistency,
weights.bin round-trip, corpus structure."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.export import read_weights_bin, write_weights_bin
from compile.model import (
    ModelConfig,
    forward,
    forward_batch,
    init_params,
    loss_fn,
    make_serve_jit,
    nll_per_token,
    param_names,
)

SMALL = dict(vocab=64, d_model=32, n_layers=2, n_heads=2, max_seq=32)


def test_forward_shapes():
    cfg = ModelConfig(**SMALL)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((cfg.max_seq,), jnp.int32)
    logits = forward(params, tokens, cfg)
    assert logits.shape == (cfg.max_seq, cfg.vocab)
    batch = jnp.zeros((3, cfg.max_seq), jnp.int32)
    assert forward_batch(params, batch, cfg).shape == (3, cfg.max_seq, cfg.vocab)


def test_initial_loss_near_uniform():
    cfg = ModelConfig(**SMALL)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(corpus.batch(cfg.vocab, 2, cfg.max_seq, seed=0))
    loss = float(loss_fn(params, tokens, cfg))
    assert abs(loss - np.log(cfg.vocab)) < 0.5, loss


def test_loss_decreases_with_one_adam_step():
    from compile.train import adam_init, adam_update

    cfg = ModelConfig(**SMALL)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adam_init(params)
    tokens = jnp.asarray(corpus.batch(cfg.vocab, 4, cfg.max_seq, seed=1))
    l0, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
    for _ in range(5):
        params, opt = adam_update(params, grads, opt, lr=1e-3)
        _, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
    l1 = loss_fn(params, tokens, cfg)
    assert float(l1) < float(l0)


def test_causality_future_tokens_do_not_affect_past_logits():
    cfg = ModelConfig(**SMALL)
    params = init_params(cfg, jax.random.PRNGKey(0))
    t1 = jnp.asarray(corpus.generate(cfg.vocab, cfg.max_seq, seed=3))
    t2 = t1.at[-1].set((t1[-1] + 5) % cfg.vocab)
    l1 = forward(params, t1, cfg)
    l2 = forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:-1]), np.asarray(l2[:-1]), atol=1e-5)


def test_prescored_full_budget_matches_exact():
    # top_k >= n ⇒ pre-scoring selects everything ⇒ identical to exact.
    cfg_e = ModelConfig(**SMALL, attention="exact")
    cfg_p = ModelConfig(**SMALL, attention="prescored", top_k=SMALL["max_seq"])
    params = init_params(cfg_e, jax.random.PRNGKey(0))
    tokens = jnp.asarray(corpus.generate(cfg_e.vocab, cfg_e.max_seq, seed=4))
    le = forward(params, tokens, cfg_e)
    lp = forward(params, tokens, cfg_p)
    np.testing.assert_allclose(np.asarray(le), np.asarray(lp), atol=5e-4, rtol=1e-4)


def test_prescored_restricted_budget_runs_and_differs():
    cfg_e = ModelConfig(**SMALL, attention="exact")
    cfg_p = ModelConfig(**SMALL, attention="prescored", top_k=8)
    params = init_params(cfg_e, jax.random.PRNGKey(0))
    tokens = jnp.asarray(corpus.batch(cfg_e.vocab, 2, cfg_e.max_seq, seed=5))
    nll_e = nll_per_token(params, tokens, cfg_e)
    nll_p = nll_per_token(params, tokens, cfg_p)
    assert nll_p.shape == nll_e.shape
    assert np.all(np.isfinite(np.asarray(nll_p)))
    assert float(jnp.abs(nll_p - nll_e).max()) > 1e-6  # budget actually binds


def test_serve_fn_outputs():
    cfg = ModelConfig(**SMALL)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fn, names = make_serve_jit(cfg)
    args = [params[n] for n in names]
    tokens = jnp.asarray(corpus.batch(cfg.vocab, 2, cfg.max_seq, seed=6))
    nll, last = fn(*args, tokens)
    assert nll.shape == (2, cfg.max_seq - 1)
    assert last.shape == (2, cfg.vocab)
    # nll consistent with direct computation
    direct = nll_per_token(params, tokens, cfg)
    np.testing.assert_allclose(np.asarray(nll), np.asarray(direct), atol=1e-5)


def test_param_names_stable_and_sorted():
    cfg = ModelConfig(**SMALL)
    names = param_names(cfg)
    assert names == sorted(names)
    params = init_params(cfg, jax.random.PRNGKey(1))
    assert set(names) == set(params.keys())


def test_weights_bin_roundtrip():
    cfg = ModelConfig(**SMALL)
    params = {k: np.asarray(v) for k, v in init_params(cfg, jax.random.PRNGKey(0)).items()}
    names = param_names(cfg)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "w.bin")
        write_weights_bin(path, params, names)
        back = read_weights_bin(path)
    assert set(back.keys()) == set(names)
    for n in names:
        np.testing.assert_array_equal(back[n], params[n].astype(np.float32))


def test_corpus_structure():
    toks = corpus.generate(128, 2048, seed=0)
    assert toks.shape == (2048,)
    assert toks.min() >= 0 and toks.max() < 128
    assert toks[0] == corpus.BOS
    # anchors and recalls occur
    assert np.sum(toks == corpus.ANCHOR) > 5
    assert np.sum(toks == corpus.RECALL) > 5
    # recall is followed by the most recent entity (check a few)
    anchors = np.where(toks[:-1] == corpus.ANCHOR)[0]
    recalls = np.where(toks[:-1] == corpus.RECALL)[0]
    checked = 0
    for r in recalls:
        prior = anchors[anchors < r]
        if len(prior) == 0:
            continue
        entity = toks[prior[-1] + 1]
        if toks[prior[-1] + 1] >= corpus.FIRST_WORD:
            assert toks[r + 1] == entity
            checked += 1
    assert checked > 3


def test_corpus_deterministic():
    a = corpus.generate(64, 256, seed=9)
    b = corpus.generate(64, 256, seed=9)
    np.testing.assert_array_equal(a, b)
    c = corpus.generate(64, 256, seed=10)
    assert np.any(a != c)
