"""Build-time ViT training on the synthetic image dataset.

Saves ``artifacts/vit_weights.bin`` (+ npz + log). The Rust substrate then
runs the §5.3 zero-shot substitution sweeps on these weights.

Usage: python -m compile.train_vit [--steps 400] [--out ../artifacts]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import vit_data
from .export import write_weights_bin
from .train import adam_init, adam_update
from .vit_model import ViTConfig, accuracy, init_params, loss_fn, param_names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--train-size", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()

    cfg = ViTConfig()
    os.makedirs(args.out, exist_ok=True)
    xs, ys = vit_data.dataset(args.train_size, num_classes=cfg.num_classes, seed=args.seed)
    xs_val, ys_val = vit_data.dataset(500, num_classes=cfg.num_classes, seed=args.seed + 777)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xs_val, ys_val = jnp.asarray(xs_val), jnp.asarray(ys_val)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, xb, yb, cfg))(params)
        params, opt = adam_update(params, grads, opt, lr=1e-3)
        return params, opt, loss

    rng = np.random.default_rng(args.seed)
    log = []
    t0 = time.time()
    for step in range(args.steps):
        idx = rng.choice(len(ys), args.batch, replace=False)
        params, opt, loss = step_fn(params, opt, xs[idx], ys[idx])
        if step % 50 == 0 or step == args.steps - 1:
            acc = float(accuracy(params, xs_val, ys_val, cfg))
            log.append({"step": step, "loss": float(loss), "val_acc": acc, "s": time.time() - t0})
            print(f"step {step:4d} loss {float(loss):.4f} val_acc {acc:.4f}", flush=True)

    names = param_names(cfg)
    np.savez(os.path.join(args.out, "vit_weights.npz"), **{k: np.asarray(v) for k, v in params.items()})
    write_weights_bin(os.path.join(args.out, "vit_weights.bin"), params, names)
    with open(os.path.join(args.out, "vit_train_log.json"), "w") as f:
        json.dump({"config": cfg.to_dict(), "log": log}, f, indent=2)
    print("ViT weights exported.")


if __name__ == "__main__":
    main()
