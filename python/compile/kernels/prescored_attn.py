"""Layer-1 Pallas kernel: pre-scored (selected-key) blockwise attention.

The paper's compute hot-spot — attention restricted to a pre-scored key
subset (Algorithm 2 line 5) — as a Pallas kernel with the FlashAttention
online-softmax schedule re-thought for TPU:

* the pre-scoring *gather* (K[S], V[S]) happens once outside the kernel, so
  the inner tiles stay dense and MXU-friendly (the TPU re-thinking of the
  paper's "restrict computation to a prioritized subset" — see DESIGN.md
  §Hardware-Adaptation);
* Q is tiled into ``(block_q, d)`` VMEM blocks via BlockSpec; selected K/V
  stream through VMEM in ``(block_k, d)`` tiles along a grid dimension;
* online-softmax accumulators (running max ``m``, denominator ``l``, output
  accumulator ``acc``) are carried across the key-tile grid dimension in VMEM
  scratch;
* causal masking uses the *original* positions of the gathered keys
  (``kpos``), prefetched as a scalar operand.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is the correctness path; TPU performance is
estimated structurally in DESIGN.md / EXPERIMENTS.md §Perf.

VMEM footprint per grid step (f32 words):
    block_q·d  (Q tile) + 2·block_k·d (K,V tiles) + block_q·block_k (scores)
  + block_q·(d + 2)     (accumulators)
Defaults block_q = block_k = 128, d = 64 → ≈ 0.33 MB ≪ 16 MB VMEM, leaving
ample room for double-buffering the K/V stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30  # finite stand-in for -inf inside the kernel (avoids NaNs)


def _attn_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, causal, scale, kv_steps):
    """One (q-tile, k-tile) grid step of online-softmax attention.

    Grid = (num_q_blocks, num_k_blocks); the k dimension is the minor
    (fastest-varying) one, so the scratch accumulators carry state across
    k steps for a fixed q tile.
    """
    kv_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # [bq, d]
    k = k_ref[...]  # [bk, d]
    v = v_ref[...]  # [bk, d]

    # [bq, bk] scores on the MXU.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale

    if causal:
        qp = qpos_ref[...]  # [bq] absolute query positions
        kp = kpos_ref[...]  # [bk] original positions of gathered keys
        mask = kp[None, :] > qp[:, None]
        s = jnp.where(mask, NEG_INF, s)

    m_prev = m_ref[...]  # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    # Guard: when every score seen so far is masked, m_cur is still NEG_INF;
    # subtracting it verbatim would give exp(0)=1 for masked entries. Clamp
    # the subtrahend so masked scores underflow to exactly 0 instead.
    m_safe = jnp.maximum(m_cur, 0.5 * NEG_INF)
    correction = jnp.exp(m_prev - m_safe) * (m_prev > NEG_INF)
    p = jnp.exp(s - m_safe[:, None])  # [bq, bk]

    l_ref[...] = l_ref[...] * correction + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kv_idx == kv_steps - 1)
    def _finalize():
        l = l_ref[...]
        inv = jnp.where(l > 0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[...] = (acc_ref[...] * inv[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def selected_attention_pallas(
    q,
    k_sel,
    v_sel,
    kpos,
    *,
    causal=True,
    block_q=DEFAULT_BLOCK_Q,
    block_k=DEFAULT_BLOCK_K,
    interpret=True,
):
    """Attention over a gathered key subset via the Pallas kernel.

    Args:
      q: [n, d] queries (positions 0..n-1).
      k_sel, v_sel: [s, d] gathered keys/values.
      kpos: [s] int32 original positions of the gathered keys.
      causal: mask keys at positions after the query.

    Returns [n, d].
    """
    n, d = q.shape
    s, _ = k_sel.shape
    bq = min(block_q, n)
    bk = min(block_k, s)
    # Pad to tile multiples; padded keys get position +inf so they are always
    # masked (causal) or zero-scored via an explicit validity mask.
    n_pad = (bq - n % bq) % bq
    s_pad = (bk - s % bk) % bk
    qp = jnp.pad(q, ((0, n_pad), (0, 0)))
    kp_ = jnp.pad(k_sel, ((0, s_pad), (0, 0)))
    vp = jnp.pad(v_sel, ((0, s_pad), (0, 0)))
    # Padded key positions: one past the end so causal masking removes them.
    # For non-causal we pass a validity trick: positions <= n-1 are real.
    kpos_p = jnp.pad(kpos.astype(jnp.int32), (0, s_pad), constant_values=jnp.int32(2**30))
    qpos = jnp.arange(n + n_pad, dtype=jnp.int32)

    if not causal:
        # Mask padded keys by treating them as "future" beyond any query and
        # enabling the causal comparison only for the padding sentinel.
        # Simpler: fold validity into kpos via the same comparison by giving
        # real keys position -1 (always allowed).
        kpos_p = jnp.where(jnp.arange(s + s_pad) < s, -1, 2**30).astype(jnp.int32)

    kv_steps = (s + s_pad) // bk
    scale = 1.0 / (d ** 0.5)

    grid = ((n + n_pad) // bq, kv_steps)
    kernel = functools.partial(
        _attn_kernel, causal=True, scale=scale, kv_steps=kv_steps
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda qi, ki: (qi,)),  # qpos
            pl.BlockSpec((bk,), lambda qi, ki: (ki,)),  # kpos
            pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),  # q
            pl.BlockSpec((bk, d), lambda qi, ki: (ki, 0)),  # k
            pl.BlockSpec((bk, d), lambda qi, ki: (ki, 0)),  # v
        ],
        out_specs=pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),  # m (running max)
            pltpu.VMEM((bq,), jnp.float32),  # l (denominator)
        ],
        interpret=interpret,
    )(qpos, kpos_p, qp, kp_, vp)
    return out[:n]


def selected_attention_heads(q, k_sel, v_sel, kpos, *, causal=True, interpret=True):
    """vmap over heads: q [H, n, d], k_sel/v_sel [H, s, d], kpos [H, s]."""
    fn = functools.partial(selected_attention_pallas, causal=causal, interpret=interpret)
    return jax.vmap(fn)(q, k_sel, v_sel, kpos)
