"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests/test_kernel.py``) asserts allclose between kernel and oracle
under hypothesis-swept shapes. These functions are also used directly by the
L2 model for the *exact*-attention variants.
"""

import jax.numpy as jnp


def exact_attention(q, k, v, *, causal=False, scale=None):
    """Standard softmax attention. q: [n, d], k/v: [s, d] -> [n, d]."""
    n, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = (q @ k.T) * scale
    if causal:
        i = jnp.arange(n)[:, None]
        j = jnp.arange(k.shape[0])[None, :]
        scores = jnp.where(j > i, -jnp.inf, scores)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def selected_attention(q, k_sel, v_sel, kpos, *, causal=True, scale=None):
    """Attention restricted to a gathered key subset (Algorithm 2 line 5).

    q: [n, d]; k_sel/v_sel: [s, d] gathered keys/values; kpos: [s] original
    positions of the gathered keys (for causal masking). Queries are at
    positions 0..n-1.
    """
    n, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = (q @ k_sel.T) * scale
    if causal:
        qpos = jnp.arange(n)[:, None]
        scores = jnp.where(kpos[None, :] > qpos, -jnp.inf, scores)
    m = scores.max(axis=-1, keepdims=True)
    # Fully-masked rows: make them zeros rather than NaN.
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m)
    denom = p.sum(axis=-1, keepdims=True)
    return jnp.where(denom > 0, (p @ v_sel) / jnp.maximum(denom, 1e-30), 0.0)


def kmeans_assign(x, centroids):
    """Nearest-centroid assignment. x: [n, d], centroids: [k, d] -> ([n], [n,k])."""
    d2 = (
        (x * x).sum(-1)[:, None]
        - 2.0 * x @ centroids.T
        + (centroids * centroids).sum(-1)[None, :]
    )
    return jnp.argmin(d2, axis=-1), d2


def kmeans_step(x, centroids):
    """One Lloyd iteration. Returns (new_centroids, assignment)."""
    assign, _ = kmeans_assign(x, centroids)
    k = centroids.shape[0]
    one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    counts = one_hot.sum(0)  # [k]
    sums = one_hot.T @ x  # [k, d]
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
    return new, assign
