"""Layer-1 Pallas kernel: the k-means distance hot loop of pre-scoring.

Pre-scoring's clustering route costs O(n·d·k·I) (§3.1), dominated by the
pairwise squared-distance computation between n keys and k centroids. This
kernel tiles the keys into ``(block_n, d)`` VMEM blocks while the full
centroid matrix (k = d+1 ≪ n rows) stays resident in VMEM, expressing the
distances through a single MXU matmul per tile via the expansion
``||x−c||² = ||x||² − 2·x·cᵀ + ||c||²``.

Lloyd's update step (segment mean) is cheap and stays in jnp; only the
distance computation is a kernel. ``interpret=True`` for CPU correctness —
see prescored_attn.py for the rationale.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(x_ref, c_ref, o_ref):
    """One key tile: o = ||x||² − 2 x cᵀ + ||c||²  ([bn, k])."""
    x = x_ref[...]  # [bn, d]
    c = c_ref[...]  # [k, d]
    xx = (x * x).sum(axis=-1, keepdims=True)  # [bn, 1]
    cc = (c * c).sum(axis=-1)[None, :]  # [1, k]
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bn, k]
    o_ref[...] = xx - 2.0 * xc + cc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pairwise_sq_dists_pallas(x, centroids, *, block_n=256, interpret=True):
    """Squared euclidean distances. x: [n, d], centroids: [k, d] -> [n, k]."""
    n, d = x.shape
    k = centroids.shape[0]
    bn = min(block_n, n)
    pad = (bn - n % bn) % bn
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _dist_kernel,
        grid=((n + pad) // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids resident
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, k), jnp.float32),
        interpret=interpret,
    )(xp, centroids)
    return out[:n]


def kmeans_lloyd(x, k, iters, *, interpret=True):
    """Fixed-iteration Lloyd's k-means, fully jittable (static shapes).

    Deterministic initialization from evenly-spaced rows (the AOT graph must
    be reproducible; k-means++ randomness lives in the Rust substrate where
    sweeps need it). Returns (centroids [k, d], assignment [n], d2 [n]).
    """
    n = x.shape[0]
    init_idx = jnp.linspace(0, n - 1, k).astype(jnp.int32)
    centroids = x[init_idx]

    def step(c, _):
        d2 = pairwise_sq_dists_pallas(x, c, interpret=interpret)
        assign = jnp.argmin(d2, axis=-1)
        one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
        counts = one_hot.sum(0)
        sums = one_hot.T @ x
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), c)
        return new, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    d2 = pairwise_sq_dists_pallas(x, centroids, interpret=interpret)
    assign = jnp.argmin(d2, axis=-1)
    return centroids, assign, d2[jnp.arange(n), assign]
