"""weights.bin writer — the binary format shared with rust/src/model/weights.rs.

Layout (little-endian):
  magic   u32 = 0x50524557  ("PREW")
  version u32 = 1
  count   u32 = number of tensors
  per tensor, in the exact order given (sorted param names — the same order
  the AOT entry point takes its positional arguments):
    name_len u32, name bytes (utf-8)
    ndim     u32, dims u32 × ndim
    data     f32 × prod(dims)
"""

import struct

import numpy as np

MAGIC = 0x50524557
VERSION = 1


def write_weights_bin(path: str, params: dict, names: list) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(names)))
        for name in names:
            arr = np.ascontiguousarray(np.asarray(params[name], dtype=np.float32))
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_weights_bin(path: str) -> dict:
    """Reader (used by tests to verify the round-trip)."""
    out = {}
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        assert magic == MAGIC and version == VERSION, "bad weights.bin header"
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out[name] = data
    return out
