"""Synthetic structured-image dataset — Python mirror of rust/src/data/images.rs.

The class structure (anchor cells + pattern kind) is a closed-form function
of the label shared with the Rust generator, so a ViT trained here transfers
to Rust-generated evaluation images; only the background noise is sampled
per-image.
"""

import numpy as np


def class_anchors(label: int, g: int):
    a1 = ((label * 7 + 3) % g, (label * 3 + 1) % g)
    a2 = ((label * 5 + 2) % g, (label * 11 + 5) % g)
    if a2 == a1:
        a2 = ((a1[0] + 1) % g, a1[1])
    return a1, a2


def _pattern(kind: int, p: int):
    di = np.arange(p)[:, None]
    dj = np.arange(p)[None, :]
    if kind == 0:  # diagonal bar
        return (np.abs(di - dj) <= 1).astype(np.float32)
    if kind == 1:  # centered blob
        cx = p / 2 - 0.5
        r2 = (di - cx) ** 2 + (dj - cx) ** 2
        return np.exp(-(r2 / p)).astype(np.float32)
    return (((di // 2 + dj // 2) % 2) == 0).astype(np.float32)  # checker


def sample_image(label: int, rng, size=64, patch=8):
    """One size×size image of class `label` in [0,1]."""
    fx = 0.1 + 0.2 * rng.random()
    fy = 0.1 + 0.2 * rng.random()
    ii = np.arange(size)[:, None]
    jj = np.arange(size)[None, :]
    px = 0.35 + 0.08 * np.sin(ii * fx) * np.cos(jj * fy) + rng.normal(0, 0.05, (size, size))
    g = size // patch
    a1, a2 = class_anchors(label, g)
    kind = label % 3
    for gi, gj in (a1, a2):
        pat = _pattern(kind, patch)
        r0, c0 = gi * patch, gj * patch
        blk = px[r0 : r0 + patch, c0 : c0 + patch]
        px[r0 : r0 + patch, c0 : c0 + patch] = (
            blk * (1 - 0.9) + 0.9 * pat + rng.normal(0, 0.01, (patch, patch))
        )
    # distractor: next class's pattern, lower contrast, random cell
    dk = (label + 1) % 3
    gi, gj = rng.integers(0, g), rng.integers(0, g)
    pat = _pattern(dk, patch)
    r0, c0 = gi * patch, gj * patch
    blk = px[r0 : r0 + patch, c0 : c0 + patch]
    px[r0 : r0 + patch, c0 : c0 + patch] = (
        blk * (1 - 0.4) + 0.4 * pat + rng.normal(0, 0.01, (patch, patch))
    )
    return np.clip(px, 0.0, 1.0).astype(np.float32)


def to_patches(px: np.ndarray, patch=8):
    """[size,size] -> [g*g, patch*patch]."""
    size = px.shape[0]
    g = size // patch
    out = np.empty((g * g, patch * patch), np.float32)
    for gi in range(g):
        for gj in range(g):
            out[gi * g + gj] = px[
                gi * patch : (gi + 1) * patch, gj * patch : (gj + 1) * patch
            ].reshape(-1)
    return out


def dataset(n: int, num_classes=10, size=64, patch=8, seed=0):
    """Returns (patches [n, g*g, p*p], labels [n])."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for i in range(n):
        label = i % num_classes
        xs.append(to_patches(sample_image(label, rng, size, patch), patch))
        ys.append(label)
    return np.stack(xs), np.asarray(ys, np.int32)
