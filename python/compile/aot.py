"""AOT lowering: JAX model → HLO **text** artifacts + weights.bin.

Interchange format is HLO text, NOT ``.serialize()``: jax ≥ 0.5 emits protos
with 64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts written (all consumed by ``rust/src/runtime``):
  artifacts/model_exact_b{B}_n{N}.hlo.txt
  artifacts/model_prescored_b{B}_n{N}_k{K}.hlo.txt
  artifacts/weights.bin      — ordered f32 tensors (see export.py)
  artifacts/manifest.txt     — model config + per-artifact entry signature

Usage: python -m compile.aot [--out ../artifacts] [--steps 300]
(trains first if weights.npz is missing).
"""

import argparse
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .export import write_weights_bin
from .model import ModelConfig, make_serve_jit, param_names


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: ModelConfig, params, batch: int, out_dir: str, tag: str) -> str:
    """Lower one serving graph and write its HLO text. Returns filename."""
    fn, names = make_serve_jit(cfg)
    example = [jax.ShapeDtypeStruct(np.asarray(params[n]).shape, jnp.float32) for n in names]
    tokens_spec = jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32)
    lowered = fn.lower(*example, tokens_spec)
    text = to_hlo_text(lowered)
    fname = f"model_{tag}_b{batch}_n{cfg.max_seq}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"wrote {fname} ({len(text)/1e6:.1f} MB)", flush=True)
    return fname


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    )
    ap.add_argument("--steps", type=int, default=300, help="training steps if weights missing")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4])
    ap.add_argument("--top-k", type=int, default=64)
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    weights_npz = os.path.join(out, "weights.npz")
    if not os.path.exists(weights_npz):
        print("weights.npz missing — training first...", flush=True)
        subprocess.check_call(
            [sys.executable, "-m", "compile.train", "--steps", str(args.steps), "--out", out],
            cwd=os.path.dirname(os.path.dirname(__file__)),
        )
    params = dict(np.load(weights_npz))

    base = ModelConfig()
    names = param_names(base)
    write_weights_bin(os.path.join(out, "weights.bin"), params, names)

    manifest = [f"# prescored-attention artifacts", f"config {base.to_dict()}"]
    for b in args.batches:
        exact_cfg = ModelConfig(attention="exact")
        f1 = lower_variant(exact_cfg, params, b, out, "exact")
        pres_cfg = ModelConfig(attention="prescored", top_k=args.top_k)
        f2 = lower_variant(pres_cfg, params, b, out, f"prescored_k{args.top_k}")
        manifest.append(f"artifact {f1} entry=(params...,tokens[i32 {b}x{base.max_seq}]) -> (nll,last_logits)")
        manifest.append(f"artifact {f2} entry=(params...,tokens[i32 {b}x{base.max_seq}]) -> (nll,last_logits)")
    manifest.append("params_order " + " ".join(names))
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("manifest written; AOT complete.", flush=True)


if __name__ == "__main__":
    main()
