"""Synthetic long-range corpus (build-time mirror of rust/src/data/corpus.rs).

The LongBench/ChatGLM substitution (DESIGN.md): a token stream with
*anchored long-range structure* so that a handful of keys per context are
globally informative — the property pre-scoring exploits:

* background tokens follow a Zipf-weighted order-1 Markov chain (local
  syntax);
* periodically an ANCHOR token introduces an "entity" token; much later a
  RECALL token is followed by the most recent entity (long-range copy);
* a small set of delimiter tokens recurs (attention-sink-like).

A model must attend to the (distant) anchor positions to predict the token
after RECALL, so heavy keys genuinely exist.

The generator is deterministic given (seed) via a PCG-compatible xorshift so
Python (training data) and Rust (serving workload) produce the same
distributions. Token map: 0 = BOS, 1 = ANCHOR, 2 = RECALL, 3..10 = delimiters,
11..vocab-1 = ordinary tokens / entities.
"""

import numpy as np

BOS, ANCHOR, RECALL = 0, 1, 2
DELIMS = list(range(3, 11))
FIRST_WORD = 11


def generate(vocab: int, length: int, seed: int) -> np.ndarray:
    """One document of `length` tokens."""
    rng = np.random.default_rng(seed)
    n_words = vocab - FIRST_WORD
    # Zipf weights over ordinary words.
    ranks = np.arange(1, n_words + 1, dtype=np.float64)
    zipf = 1.0 / ranks**1.1
    zipf /= zipf.sum()
    # Order-1 Markov: each word prefers a small successor set.
    succ = rng.integers(0, n_words, size=(n_words, 4))

    out = np.empty(length, dtype=np.int32)
    out[0] = BOS
    entity = FIRST_WORD + int(rng.integers(0, n_words))
    prev_word = 0
    i = 1
    while i < length:
        r = rng.random()
        if r < 0.02:
            out[i] = ANCHOR
            i += 1
            if i < length:
                entity = FIRST_WORD + int(rng.integers(0, n_words))
                out[i] = entity
                i += 1
        elif r < 0.05:
            out[i] = RECALL
            i += 1
            if i < length:
                out[i] = entity  # long-range copy of the latest entity
                i += 1
        elif r < 0.12:
            out[i] = DELIMS[int(rng.integers(0, len(DELIMS)))]
            i += 1
        else:
            if rng.random() < 0.7:
                w = int(succ[prev_word, int(rng.integers(0, 4))])
            else:
                w = int(rng.choice(n_words, p=zipf))
            out[i] = FIRST_WORD + w
            prev_word = w
            i += 1
    return out


def batch(vocab: int, batch_size: int, length: int, seed: int) -> np.ndarray:
    """[batch_size, length] int32 batch of independent documents."""
    return np.stack([generate(vocab, length, seed * 10_007 + b) for b in range(batch_size)])
