"""Tiny ViT (build-time Python) for the zero-shot substitution experiments.

Patch embedding + class token + pre-LN encoder blocks with full softmax
attention + linear head. Trained here with exact attention; the *substituted*
attention variants (k-means / leverage restricted) are evaluated in the Rust
substrate (rust/src/model/vit.rs) on the exported weights, matching the
paper's "replace self-attention in a pretrained ViT" protocol (§5.3).

Parameter naming mirrors the LM so weights.bin export is shared.
"""

import jax
import jax.numpy as jnp

from .kernels import ref


class ViTConfig:
    def __init__(self, patch_dim=64, num_patches=64, d_model=64, n_layers=3, n_heads=4, num_classes=10):
        assert d_model % n_heads == 0
        self.patch_dim = patch_dim
        self.num_patches = num_patches
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.num_classes = num_classes

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    @property
    def seq(self):
        return self.num_patches + 1  # + class token

    def to_dict(self):
        return dict(
            patch_dim=self.patch_dim,
            num_patches=self.num_patches,
            d_model=self.d_model,
            n_layers=self.n_layers,
            n_heads=self.n_heads,
            num_classes=self.num_classes,
        )


def init_params(cfg: ViTConfig, key):
    d = cfg.d_model
    keys = jax.random.split(key, 4 + cfg.n_layers * 6)
    p = {
        "patch_w": jax.random.normal(keys[0], (cfg.patch_dim, d), jnp.float32) * (cfg.patch_dim**-0.5),
        "patch_b": jnp.zeros((d,), jnp.float32),
        "cls": jax.random.normal(keys[1], (d,), jnp.float32) * 0.02,
        "pos": jax.random.normal(keys[2], (cfg.seq, d), jnp.float32) * 0.02,
        "ln_f.g": jnp.ones((d,), jnp.float32),
        "ln_f.b": jnp.zeros((d,), jnp.float32),
        "head": jax.random.normal(keys[3], (d, cfg.num_classes), jnp.float32) * 0.02,
    }
    h = 4 * d
    for l in range(cfg.n_layers):
        kk = keys[4 + l * 6 : 4 + (l + 1) * 6]
        p[f"l{l}.ln1.g"] = jnp.ones((d,), jnp.float32)
        p[f"l{l}.ln1.b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{l}.wq"] = jax.random.normal(kk[0], (d, d), jnp.float32) * (d**-0.5)
        p[f"l{l}.wk"] = jax.random.normal(kk[1], (d, d), jnp.float32) * (d**-0.5)
        p[f"l{l}.wv"] = jax.random.normal(kk[2], (d, d), jnp.float32) * (d**-0.5)
        p[f"l{l}.wo"] = jax.random.normal(kk[3], (d, d), jnp.float32) * (d**-0.5)
        p[f"l{l}.ln2.g"] = jnp.ones((d,), jnp.float32)
        p[f"l{l}.ln2.b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{l}.w1"] = jax.random.normal(kk[4], (d, h), jnp.float32) * (d**-0.5)
        p[f"l{l}.b1"] = jnp.zeros((h,), jnp.float32)
        p[f"l{l}.w2"] = jax.random.normal(kk[5], (h, d), jnp.float32) * (h**-0.5)
        p[f"l{l}.b2"] = jnp.zeros((d,), jnp.float32)
    return p


def param_names(cfg: ViTConfig):
    return sorted(init_params(cfg, jax.random.PRNGKey(0)).keys())


def _ln(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(params, patches, cfg: ViTConfig):
    """patches: [num_patches, patch_dim] -> logits [num_classes]."""
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    x = patches @ params["patch_w"] + params["patch_b"]
    x = jnp.concatenate([params["cls"][None, :], x], axis=0) + params["pos"]
    n = x.shape[0]
    for l in range(cfg.n_layers):
        h = _ln(x, params[f"l{l}.ln1.g"], params[f"l{l}.ln1.b"])
        q = (h @ params[f"l{l}.wq"]).reshape(n, H, dh).transpose(1, 0, 2)
        k = (h @ params[f"l{l}.wk"]).reshape(n, H, dh).transpose(1, 0, 2)
        v = (h @ params[f"l{l}.wv"]).reshape(n, H, dh).transpose(1, 0, 2)
        att = jax.vmap(lambda qq, kk, vv: ref.exact_attention(qq, kk, vv, causal=False))(q, k, v)
        x = x + att.transpose(1, 0, 2).reshape(n, d) @ params[f"l{l}.wo"]
        h2 = _ln(x, params[f"l{l}.ln2.g"], params[f"l{l}.ln2.b"])
        x = x + jax.nn.gelu(h2 @ params[f"l{l}.w1"] + params[f"l{l}.b1"]) @ params[f"l{l}.w2"] + params[f"l{l}.b2"]
    x = _ln(x, params["ln_f.g"], params["ln_f.b"])
    return x[0] @ params["head"]  # class token readout


def loss_fn(params, patches, labels, cfg: ViTConfig):
    logits = jax.vmap(lambda p: forward(params, p, cfg))(patches)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1).mean()


def accuracy(params, patches, labels, cfg: ViTConfig):
    logits = jax.vmap(lambda p: forward(params, p, cfg))(patches)
    return (logits.argmax(-1) == labels).mean()
