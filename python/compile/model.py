"""Layer 2: the JAX transformer LM (build-time only).

A small pre-LN causal transformer whose attention layer is pluggable:

* ``attention="exact"``     — full softmax attention (training + the exact
  baseline artifact);
* ``attention="prescored"`` — Algorithm 2 inside the graph: per-head k-means
  pre-scoring of the keys (fixed-iteration Lloyd via the Pallas distance
  kernel), top-k selection with a forced attention-sink anchor at position 0,
  and the Pallas selected-attention kernel over the gathered keys.

Everything here is lowered ONCE by ``aot.py`` to HLO text; Python never runs
on the request path.
"""

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.kmeans import kmeans_lloyd
from .kernels.prescored_attn import selected_attention_heads

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


class ModelConfig:
    """Static model hyper-parameters (baked into the lowered graph)."""

    def __init__(
        self,
        vocab=512,
        d_model=128,
        n_layers=4,
        n_heads=4,
        max_seq=256,
        mlp_mult=4,
        attention="exact",
        top_k=64,
        kmeans_iters=4,
        interpret=True,
    ):
        assert d_model % n_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.max_seq = max_seq
        self.mlp_mult = mlp_mult
        self.attention = attention
        self.top_k = top_k
        self.kmeans_iters = kmeans_iters
        self.interpret = interpret

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    def to_dict(self):
        return dict(
            vocab=self.vocab,
            d_model=self.d_model,
            n_layers=self.n_layers,
            n_heads=self.n_heads,
            max_seq=self.max_seq,
            mlp_mult=self.mlp_mult,
            attention=self.attention,
            top_k=self.top_k,
            kmeans_iters=self.kmeans_iters,
        )


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    """Initialize parameters (scaled-normal init)."""
    d, v, h = cfg.d_model, cfg.vocab, cfg.mlp_mult * cfg.d_model
    keys = jax.random.split(key, 4 + cfg.n_layers * 6)
    params: Params = {
        "embed": jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(keys[1], (cfg.max_seq, d), jnp.float32) * 0.02,
        "ln_f.g": jnp.ones((d,), jnp.float32),
        "ln_f.b": jnp.zeros((d,), jnp.float32),
        "head": jax.random.normal(keys[2], (d, v), jnp.float32) * 0.02,
    }
    for l in range(cfg.n_layers):
        kk = keys[4 + l * 6 : 4 + (l + 1) * 6]
        params[f"l{l}.ln1.g"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.ln1.b"] = jnp.zeros((d,), jnp.float32)
        params[f"l{l}.wq"] = jax.random.normal(kk[0], (d, d), jnp.float32) * (d**-0.5)
        params[f"l{l}.wk"] = jax.random.normal(kk[1], (d, d), jnp.float32) * (d**-0.5)
        params[f"l{l}.wv"] = jax.random.normal(kk[2], (d, d), jnp.float32) * (d**-0.5)
        params[f"l{l}.wo"] = jax.random.normal(kk[3], (d, d), jnp.float32) * (d**-0.5)
        params[f"l{l}.ln2.g"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.ln2.b"] = jnp.zeros((d,), jnp.float32)
        params[f"l{l}.w1"] = jax.random.normal(kk[4], (d, h), jnp.float32) * (d**-0.5)
        params[f"l{l}.b1"] = jnp.zeros((h,), jnp.float32)
        params[f"l{l}.w2"] = jax.random.normal(kk[5], (h, d), jnp.float32) * (h**-0.5)
        params[f"l{l}.b2"] = jnp.zeros((d,), jnp.float32)
    return params


def param_names(cfg: ModelConfig):
    """Deterministic parameter ordering shared with the Rust weights loader."""
    key = jax.random.PRNGKey(0)
    return sorted(init_params(cfg, key).keys())


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _prescored_head_attention(q, k, v, cfg: ModelConfig):
    """Algorithm 2 for one layer: per-head k-means pre-scoring + Pallas
    selected-key attention. q/k/v: [H, n, dh]."""
    H, n, dh = q.shape
    s = min(cfg.top_k, n)

    def per_head(kh, vh):
        # ℓ2-normalize keys before clustering (Assumption 4.1 / Appendix B).
        norms = jnp.linalg.norm(kh, axis=-1, keepdims=True)
        kn = kh / jnp.maximum(norms, 1e-6)
        _, _, dist = kmeans_lloyd(
            kn, k=dh + 1, iters=cfg.kmeans_iters, interpret=cfg.interpret
        )
        # Score = closeness to centroid; force-include position 0 as an
        # attention-sink anchor so every causal query has a valid key.
        # NOTE: selection via argsort, not lax.top_k — the image's XLA 0.5.1
        # HLO parser predates TopK's "largest" attribute (see DESIGN.md).
        score = -dist
        score = score.at[0].set(jnp.inf)
        order = jnp.argsort(-score)  # descending
        sel = jnp.sort(order[:s])
        return kh[sel], vh[sel], sel.astype(jnp.int32)

    k_sel, v_sel, kpos = jax.vmap(per_head)(k, v)  # keys drive the selection
    return selected_attention_heads(
        q, k_sel, v_sel, kpos, causal=True, interpret=cfg.interpret
    )


def forward(params: Params, tokens, cfg: ModelConfig):
    """Causal LM forward for one sequence. tokens: [n] int32 -> logits [n, V]."""
    n = tokens.shape[0]
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    x = params["embed"][tokens] + params["pos"][:n]
    for l in range(cfg.n_layers):
        h = _layernorm(x, params[f"l{l}.ln1.g"], params[f"l{l}.ln1.b"])
        q = (h @ params[f"l{l}.wq"]).reshape(n, H, dh).transpose(1, 0, 2)
        k = (h @ params[f"l{l}.wk"]).reshape(n, H, dh).transpose(1, 0, 2)
        v = (h @ params[f"l{l}.wv"]).reshape(n, H, dh).transpose(1, 0, 2)
        if cfg.attention == "prescored":
            att = _prescored_head_attention(q, k, v, cfg)
        else:
            att = jax.vmap(lambda qq, kk, vv: ref.exact_attention(qq, kk, vv, causal=True))(
                q, k, v
            )
        att = att.transpose(1, 0, 2).reshape(n, d)
        x = x + att @ params[f"l{l}.wo"]
        h2 = _layernorm(x, params[f"l{l}.ln2.g"], params[f"l{l}.ln2.b"])
        x = x + (jax.nn.gelu(h2 @ params[f"l{l}.w1"] + params[f"l{l}.b1"])) @ params[
            f"l{l}.w2"
        ] + params[f"l{l}.b2"]
    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["head"]


def forward_batch(params: Params, tokens, cfg: ModelConfig):
    """tokens: [B, n] -> logits [B, n, V]."""
    return jax.vmap(lambda t: forward(params, t, cfg))(tokens)


def nll_per_token(params: Params, tokens, cfg: ModelConfig):
    """Per-token next-token negative log-likelihood. tokens: [B, n] ->
    nll [B, n-1] (position t predicts token t+1)."""
    logits = forward_batch(params, tokens, cfg)  # [B, n, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]


def loss_fn(params: Params, tokens, cfg: ModelConfig):
    """Mean next-token cross-entropy over a batch."""
    return nll_per_token(params, tokens, cfg).mean()


def serve_fn(params_list, tokens, cfg: ModelConfig, names):
    """Serving entry point (lowered to HLO): positional params + tokens.

    Returns (nll [B, n-1], last_logits [B, V]) — everything the Rust scoring
    server needs for perplexity reporting and greedy continuation.
    """
    params = dict(zip(names, params_list))
    logits = forward_batch(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll, logits[:, -1, :]


def make_serve_jit(cfg: ModelConfig):
    """A jittable positional-arg closure for AOT lowering."""
    names = param_names(cfg)

    @jax.jit
    def fn(*args):
        *params_list, tokens = args
        return serve_fn(params_list, tokens, cfg, names)

    return fn, names
