"""Build-time training of the tiny LM on the synthetic corpus.

Trains with exact attention (full-layer replacement happens only at serving
time, matching the paper's zero-shot substitution protocol), with a
hand-rolled Adam (optax is not in the image). Saves weights to
``artifacts/weights.npz`` plus a loss log for EXPERIMENTS.md.

Usage: python -m compile.train [--steps 300] [--out ../artifacts]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, init_params, loss_fn


def adam_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps: int, batch_size: int, seed: int, log_every=25):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
        params, opt = adam_update(params, grads, opt, lr=1e-3)
        return params, opt, loss

    log = []
    t0 = time.time()
    for step in range(steps):
        tokens = jnp.asarray(corpus.batch(cfg.vocab, batch_size, cfg.max_seq, seed=step))
        params, opt, loss = step_fn(params, opt, tokens)
        if step % log_every == 0 or step == steps - 1:
            loss_v = float(loss)
            log.append({"step": step, "loss": loss_v, "elapsed_s": time.time() - t0})
            print(f"step {step:4d}  loss {loss_v:.4f}  ({time.time()-t0:.1f}s)", flush=True)
    return params, log


def save_weights_npz(path, params):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()

    cfg = ModelConfig()  # training always uses exact attention
    os.makedirs(args.out, exist_ok=True)
    params, log = train(cfg, args.steps, args.batch, args.seed)
    save_weights_npz(os.path.join(args.out, "weights.npz"), params)
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump({"config": cfg.to_dict(), "steps": args.steps, "log": log}, f, indent=2)
    print(f"saved weights + log to {args.out}")


if __name__ == "__main__":
    main()
