//! Figure 2 + Tables 3/4/5 — perplexity vs top-k for K-means / K-median /
//! Leverage pre-scoring, with (sample_size = 16) and without (0) residual
//! sampling, reporting PPL (mixed lengths) and PPL* (full-length sequences
//! only — the paper's "length ≥ n_query" column).
//!
//! Paper shape: top_k = 0 + no residual is the unfiltered high-compute
//! reference (lowest PPL*); under a real budget the curves are U-shaped in
//! the GLM2 coupling and ~monotone-decreasing-then-flat in the corrected
//! GLM3 coupling; K-means ≼ K-median ≼ Leverage at small k.

use prescored::attention::{AttentionSpec, Coupling, PreScoreMode};
use prescored::exp::{eval_docs, ppl_over, prescored_spec};
use prescored::model::{Transformer, TransformerConfig, WeightStore};
use prescored::prescore::Method;
use prescored::util::bench::{f, Table};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    let model = if dir.join("weights.bin").exists() {
        let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
        Transformer::from_weights(&ws, TransformerConfig::default())
    } else {
        eprintln!("artifacts missing — using random weights");
        Transformer::random(TransformerConfig::default(), 1)
    };
    // PPL: mixed-length docs; PPL*: full-length only.
    let mixed = eval_docs(512, 256, 4, false, 31_000);
    let long = eval_docs(512, 256, 3, true, 32_000);

    let top_ks = [0usize, 8, 32, 64, 128, 192];
    for (mname, method) in [
        ("K-means", Method::KMeans),
        ("K-median", Method::KMedian),
        ("Leverage", Method::Leverage { exact: false }),
    ] {
        let mut t = Table::new(
            &format!("Tables 3–5 / Fig. 2 — {mname} pre-scoring (PPL by top-k)"),
            &["Top K", "Sample Size", "PPL", "PPL*"],
        );
        for &sample in &[16usize, 0] {
            for &k in &top_ks {
                let spec = prescored_spec(method, k, sample, Coupling::Glm3Corrected, true);
                let ppl = ppl_over(&model, &spec, &mixed);
                let ppl_star = ppl_over(&model, &spec, &long);
                t.row(vec![k.to_string(), sample.to_string(), f(ppl, 3), f(ppl_star, 3)]);
            }
        }
        t.print();
    }
    // Accuracy cost of prefix-stable streaming pre-scoring (mode=stream):
    // same K-means budget, but the selection comes from the incremental
    // centroid fold instead of a per-forward full re-cluster. The gap to
    // the full-recluster column is the price paid for suffix stability
    // (O(suffix) warm prefix-cache hits + O(|new|·k) decode refreshes).
    let mut t = Table::new(
        "Fig. 2 addendum — streaming vs full re-cluster pre-scoring (K-means, PPL by top-k)",
        &["Top K", "PPL full", "PPL stream", "PPL* full", "PPL* stream"],
    );
    for &k in &top_ks[1..] {
        // k = 0 is the unfiltered reference; the modes coincide there.
        let full = prescored_spec(Method::KMeans, k, 16, Coupling::Glm3Corrected, true);
        let stream = match &full {
            AttentionSpec::PreScored(cfg) => {
                let mut cfg = cfg.clone();
                cfg.mode = PreScoreMode::Stream;
                AttentionSpec::PreScored(cfg)
            }
            _ => unreachable!("prescored_spec builds a PreScored spec"),
        };
        t.row(vec![
            k.to_string(),
            f(ppl_over(&model, &full, &mixed), 3),
            f(ppl_over(&model, &stream, &mixed), 3),
            f(ppl_over(&model, &full, &long), 3),
            f(ppl_over(&model, &stream, &long), 3),
        ]);
    }
    t.print();

    println!("\npaper shape: k=0 (unfiltered) is the high-compute reference; curves flatten");
    println!("after a few dozen keys (denoising); residual sampling helps at small k.");
}
