//! Figures 4/5 + Table 7 — heavy-attention coverage.
//!
//! Median percentage of ε-heavy attention entries captured by K-means /
//! K-median sampled key subsets as a function of the number of sampled keys
//! (ε ∈ {0.01, 0.1, 0.3}), and the top-k heavy-*columns* coverage.
//!
//! Paper shape: coverage increases with sampled keys and with ε; K-means
//! marginally above K-median; top-k column coverage ≈ (keys sampled)/n
//! scaling of Table 7 (15.6% → 65.6% for 32 → 128 of 197 columns).

use prescored::attention::exact::attention_matrix;
use prescored::attention::AttentionInputs;
use prescored::data::images::{dataset, to_patches, ImageConfig};
use prescored::linalg::ops::matmul;
use prescored::metrics::{heavy_columns_coverage, heavy_coverage};
use prescored::model::{Vit, VitConfig, WeightStore};
use prescored::prescore::{prescore, prescore_balanced, KeyBudget, Method, PreScoreConfig};
use prescored::util::bench::{f, Table};
use prescored::util::rng::Rng;
use std::path::Path;

/// Build per-image first-layer (Q, K) from the trained ViT's projections so
/// the attention matrices reflect a *trained* model, as in the paper.
fn qk_matrices(n_images: usize) -> Vec<(prescored::linalg::Matrix, prescored::linalg::Matrix)> {
    let img_cfg = ImageConfig::default();
    let ds = dataset(&img_cfg, n_images, 55);
    let weights = Path::new("artifacts/vit_weights.bin");
    let ws = if weights.exists() {
        WeightStore::load(weights).ok()
    } else {
        None
    };
    let mut rng = Rng::new(3);
    ds.iter()
        .map(|img| {
            let patches = to_patches(img, &img_cfg);
            match &ws {
                Some(ws) => {
                    let emb = matmul(&patches, &ws.matrix("patch_w"));
                    let q = matmul(&emb, &ws.matrix("l0.wq"));
                    let k = matmul(&emb, &ws.matrix("l0.wk"));
                    (q, k)
                }
                None => {
                    let _ = Vit::random(VitConfig::default(), 1);
                    let q = prescored::linalg::Matrix::randn(patches.rows, 16, 1.0, &mut rng);
                    let k = prescored::linalg::Matrix::randn(patches.rows, 16, 1.0, &mut rng);
                    (q, k)
                }
            }
        })
        .collect()
}

fn median(xs: &mut Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let qks = qk_matrices(24);
    let budgets = [8usize, 16, 32, 48];
    let epsilons = [0.01f32, 0.1, 0.3];

    for (name, is_kmeans) in [("Figure 4 — K-means", true), ("Figure 5 — K-median", false)] {
        let mut t = Table::new(
            &format!("{name}: median % of ε-heavy entries captured vs sampled keys"),
            &["keys", "eps=0.01", "eps=0.1", "eps=0.3"],
        );
        for &s in &budgets {
            let mut cells = vec![s.to_string()];
            for &eps in &epsilons {
                let mut vals: Vec<f64> = Vec::new();
                for (q, k) in &qks {
                    let sel = if is_kmeans {
                        prescore_balanced(k, 4, s, 10, 5).selected
                    } else {
                        prescore(
                            k,
                            &PreScoreConfig {
                                method: Method::KMedian,
                                budget: KeyBudget::Fixed(s),
                                ..Default::default()
                            },
                        )
                        .selected
                    };
                    let attn = attention_matrix(&AttentionInputs::new(q, k, k));
                    vals.push(heavy_coverage(&attn, &sel, eps) * 100.0);
                }
                cells.push(f(median(&mut vals), 1));
            }
            t.row(cells);
        }
        t.print();
    }

    let mut t7 = Table::new(
        "Table 7 — top-k heavy-columns coverage (%)",
        &["Number of Keys Sampled", "Average Percentage"],
    );
    for (label, is_kmeans) in [("Kmeans", true), ("Kmedian", false)] {
        for &s in &[8usize, 16, 32] {
            let mut total = 0.0;
            for (q, k) in &qks {
                let sel = if is_kmeans {
                    prescore_balanced(k, 4, s, 10, 5).selected
                } else {
                    prescore(
                        k,
                        &PreScoreConfig { method: Method::KMedian, budget: KeyBudget::Fixed(s), ..Default::default() },
                    )
                    .selected
                };
                let attn = attention_matrix(&AttentionInputs::new(q, k, k));
                total += heavy_columns_coverage(&attn, &sel, 0.1, s);
            }
            t7.row(vec![format!("{label}-{s}"), f(total / qks.len() as f64 * 100.0, 2)]);
        }
    }
    t7.print();
    println!("\npaper shape: coverage rises with keys sampled and with ε; kmeans ≳ kmedian.");
}
