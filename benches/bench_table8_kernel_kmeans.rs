//! Table 8 (Appendix I) — Gaussian-kernel K-means pre-scoring PPL grid
//! (GLM2-era ablation; run here under both couplings for completeness).

use prescored::attention::Coupling;
use prescored::exp::{eval_docs, ppl_over, prescored_spec};
use prescored::model::{Transformer, TransformerConfig, WeightStore};
use prescored::prescore::Method;
use prescored::util::bench::{f, Table};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    let model = if dir.join("weights.bin").exists() {
        let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
        Transformer::from_weights(&ws, TransformerConfig::default())
    } else {
        eprintln!("artifacts missing — using random weights");
        Transformer::random(TransformerConfig::default(), 1)
    };
    // Kernel k-means is O(n²) per iteration — keep the eval set tight.
    let docs = eval_docs(512, 256, 2, true, 35_000);

    let mut t = Table::new(
        "Table 8 — Gaussian-kernel K-means pre-scoring (PPL)",
        &["Top K", "Sample=16 (GLM2)", "Sample=16 (GLM3)", "Sample=0 (GLM3)"],
    );
    for &k in &[8usize, 32, 64, 128] {
        let m = Method::GaussianKMeans { gamma: -1.0 };
        let glm2 = ppl_over(&model, &prescored_spec(m, k, 16, Coupling::Glm2Artifact, true), &docs);
        let glm3 = ppl_over(&model, &prescored_spec(m, k, 16, Coupling::Glm3Corrected, true), &docs);
        let nores = ppl_over(&model, &prescored_spec(m, k, 0, Coupling::Glm3Corrected, true), &docs);
        t.row(vec![k.to_string(), f(glm2, 3), f(glm3, 3), f(nores, 3)]);
    }
    t.print();
    println!("\npaper shape: kernel k-means tracks plain k-means; best at moderate-to-large k");
    println!("with residual sampling; degrades without residuals at large k (GLM2 coupling).");
}
