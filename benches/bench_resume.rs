//! Resume-vs-cold bench: the wire cost of finishing an interrupted stream
//! via `Last-Event-ID` resume, against recomputing the whole request from
//! scratch.
//!
//! The serving claim under test: a resumed session costs O(remaining
//! decode) — the parked session's KV pages are still pinned, so the
//! continuation runs no second prefill — which must beat a cold request
//! that pays prefill + full decode. If resuming were ever slower than
//! recomputing, the whole session-lifecycle layer would be dead weight.
//!
//! Emits `BENCH_resume.json` at the repo root: p50 wall time for the cold
//! full request and for the disconnect-and-resume completion, plus the
//! speedup ratio.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_RESUME_CONTEXT` — context tokens per request, default 192
//! * `PALLAS_RESUME_NEW`     — generated tokens per request, default 16
//! * `PALLAS_RESUME_REPS`    — repetitions per scenario, default 3
//! * `PALLAS_RESUME_JSON`    — output path override (CI smoke points it at
//!   a scratch file so real baselines aren't clobbered)
//! * `PALLAS_RESUME_ASSERT`  — when `1`, exit non-zero unless the resume
//!   completion beats the cold recompute

use prescored::config::ServingConfig;
use prescored::gateway::{Gateway, GatewayConfig};
use prescored::model::{Transformer, TransformerConfig};
use prescored::server::ScoringServer;
use prescored::util::bench::{env_usize, f, Table};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const SPEC: &str = "prescored:kmeans,top_k=12,block=16,sample=4";

fn start_gateway(max_seq: usize, kv_blocks: usize) -> Gateway {
    let tcfg = TransformerConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq,
    };
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        variant: "exact".into(),
        max_seq,
        attention_spec: SPEC.into(),
        executor_workers: 2,
        kv_blocks,
        ..Default::default()
    };
    let server = ScoringServer::start_with_model(cfg, Transformer::random(tcfg, 67))
        .expect("server start");
    Gateway::start(GatewayConfig::default(), server).expect("gateway start")
}

/// A minimal SSE reader: POST, then count `event: token` markers.
struct Stream {
    sock: TcpStream,
    buf: Vec<u8>,
}

impl Stream {
    fn post(addr: SocketAddr, body: &str, last_event_id: Option<&str>) -> Stream {
        let sock = TcpStream::connect(addr).expect("connect");
        sock.set_read_timeout(Some(Duration::from_secs(60))).expect("timeout");
        let mut head = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: gw\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
            body.len()
        );
        if let Some(cursor) = last_event_id {
            head.push_str(&format!("Last-Event-ID: {cursor}\r\n"));
        }
        head.push_str("\r\n");
        let mut s = Stream { sock, buf: Vec::new() };
        s.sock.write_all(head.as_bytes()).expect("write head");
        s.sock.write_all(body.as_bytes()).expect("write body");
        s
    }

    fn fill(&mut self) -> usize {
        let mut chunk = [0u8; 4096];
        match self.sock.read(&mut chunk) {
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                n
            }
            Err(_) => 0,
        }
    }

    /// HTTP status + the `X-Pallas-Session` header value (if present).
    fn read_headers(&mut self) -> (u16, Option<String>) {
        loop {
            if let Some(idx) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head =
                    String::from_utf8(self.buf[..idx].to_vec()).expect("utf8 headers");
                self.buf.drain(..idx + 4);
                let status: u16 = head
                    .split_whitespace()
                    .nth(1)
                    .and_then(|s| s.parse().ok())
                    .expect("status line");
                let sid = head.lines().find_map(|l| {
                    let (name, value) = l.split_once(':')?;
                    name.eq_ignore_ascii_case("x-pallas-session")
                        .then(|| value.trim().to_string())
                });
                return (status, sid);
            }
            assert!(self.fill() > 0, "connection closed before headers");
        }
    }

    fn count(&self, needle: &[u8]) -> usize {
        if self.buf.len() < needle.len() {
            return 0;
        }
        self.buf.windows(needle.len()).filter(|w| w == &needle).count()
    }

    /// Block until at least `n` token events are buffered.
    fn wait_tokens(&mut self, n: usize) {
        while self.count(b"event: token") < n {
            assert!(self.fill() > 0, "stream ended before {n} token events");
        }
    }

    /// Read to stream end; returns (token events seen, saw done).
    fn drain(&mut self) -> (usize, bool) {
        while self.fill() > 0 {}
        (self.count(b"event: token"), self.count(b"event: done") > 0)
    }
}

fn percentile_50(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    samples[samples.len() / 2]
}

fn main() {
    let context = env_usize("PALLAS_RESUME_CONTEXT", 192);
    let n_new = env_usize("PALLAS_RESUME_NEW", 16);
    let reps = env_usize("PALLAS_RESUME_REPS", 3);
    let assert_beat = std::env::var("PALLAS_RESUME_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_RESUME_JSON").unwrap_or_else(|_| "BENCH_resume.json".into());

    let cut = (n_new / 2).max(1);
    let max_seq = context + n_new + 8;
    let kv_blocks = (((context + n_new) / 16 + 4) * 4).max(256);
    println!(
        "== resume vs cold: context {context}, {n_new} new, disconnect after {cut}, {reps} reps =="
    );

    let gw = start_gateway(max_seq, kv_blocks);
    let addr = gw.addr();

    let mut cold_ms = Vec::new();
    let mut resume_ms = Vec::new();
    for rep in 0..reps {
        // Cold: a fresh context (unique corpus seed — never cached) paying
        // prefill + full decode.
        let body = format!(
            "{{\"corpus_len\": {context}, \"corpus_seed\": {}, \"generate\": {n_new}}}",
            1000 + rep
        );
        let t0 = Instant::now();
        let mut cold = Stream::post(addr, &body, None);
        let (status, _) = cold.read_headers();
        assert_eq!(status, 200, "cold request admitted");
        let (tokens, done) = cold.drain();
        cold_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        assert!(done, "cold stream must finish");
        assert_eq!(tokens, n_new, "cold stream must deliver every token");

        // Interrupted: stream `cut` tokens, vanish, wait for the park, then
        // time the resume completion (reconnect + remaining decode).
        let body = format!(
            "{{\"corpus_len\": {context}, \"corpus_seed\": {}, \"generate\": {n_new}}}",
            2000 + rep
        );
        let mut victim = Stream::post(addr, &body, None);
        let (status, sid) = victim.read_headers();
        assert_eq!(status, 200, "victim request admitted");
        let sid = sid.expect("session header");
        victim.wait_tokens(cut);
        let before = gw.stats();
        drop(victim);
        // The gateway parks (or finishes) the session at its next write;
        // wait for the attachment to end before timing the resume.
        let parked_deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let s = gw.stats();
            if s.sessions_parked > before.sessions_parked || s.completed > before.completed {
                break;
            }
            assert!(Instant::now() < parked_deadline, "session never parked");
            std::thread::sleep(Duration::from_millis(2));
        }
        let t0 = Instant::now();
        let mut resumed = loop {
            let mut r = Stream::post(addr, "", Some(&format!("{sid}:{cut}")));
            let (status, _) = r.read_headers();
            match status {
                200 => break r,
                409 => std::thread::sleep(Duration::from_millis(2)),
                other => panic!("resume refused with {other}"),
            }
        };
        let (tokens, done) = resumed.drain();
        resume_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        assert!(done, "resumed stream must finish");
        assert!(
            tokens >= n_new - cut,
            "resume must deliver the remaining tokens ({tokens} < {})",
            n_new - cut
        );
    }

    let stats = gw.shutdown();
    assert_eq!(
        stats.kv_pages_acquired, stats.kv_pages_released,
        "bench run must balance page accounting"
    );

    let cold_p50 = percentile_50(&mut cold_ms);
    let resume_p50 = percentile_50(&mut resume_ms);
    let speedup = cold_p50 / resume_p50.max(1e-9);
    let mut table = Table::new("resume vs cold", &["scenario", "wall p50 (ms)"]);
    table.row(vec!["cold full request".into(), f(cold_p50, 2)]);
    table.row(vec!["disconnect + resume".into(), f(resume_p50, 2)]);
    table.print();
    println!("speedup: {speedup:.2}x");

    let json = format!(
        "{{\n  \"context\": {context},\n  \"new_tokens\": {n_new},\n  \"cut\": {cut},\n  \"cold_ms_p50\": {cold_p50:.3},\n  \"resume_ms_p50\": {resume_p50:.3},\n  \"speedup\": {speedup:.3}\n}}\n"
    );
    std::fs::write(&json_path, json).expect("writing BENCH_resume.json");
    println!("wrote {json_path}");

    if assert_beat {
        if resume_p50 >= cold_p50 {
            eprintln!(
                "ASSERT FAILED: resume completion {resume_p50:.2} ms is not faster than \
                 cold recompute {cold_p50:.2} ms — a resumed session must cost only the \
                 remaining decode, never a second prefill"
            );
            std::process::exit(1);
        }
        println!("assert ok: resume {resume_p50:.2} ms < cold {cold_p50:.2} ms");
    }
}
