//! Figure 1 (a: forward, b: forward+backward): single-layer speedup over
//! FlashAttention for HyperAttention and the pre-scored variants, as a
//! function of sequence length.
//!
//! Paper shape to reproduce: all Hyper-based methods overtake FlashAttention
//! at long n (speedup grows with n); pre-scored variants track plain
//! HyperAttention with a small overhead gap (the O(n·d) pre-scoring cost),
//! with Lev+Hyper scaling best among the pre-scored ones.

use prescored::attention::backward::{exact_attention_backward, sparse_attention_backward};
use prescored::attention::{
    flash_attention, hyper_attention, prescored_hyper_attention, AttentionInputs, Coupling,
    HyperConfig, PreScoredConfig,
};
use prescored::linalg::Matrix;
use prescored::prescore::{Method, PreScoreConfig};
use prescored::util::bench::{black_box, f, Bencher, Table};
use prescored::util::rng::Rng;

fn qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    (
        Matrix::randn(n, d, 1.0, &mut rng),
        Matrix::randn(n, d, 1.0, &mut rng),
        Matrix::randn(n, d, 1.0, &mut rng),
    )
}

fn prescored_cfg(method: Method, n: usize) -> PreScoredConfig {
    PreScoredConfig {
        prescore: PreScoreConfig { method, top_k: n / 4, max_iters: 3, ..Default::default() },
        hyper: HyperConfig { block_size: 64, sample_size: 16, ..Default::default() },
        fallback_delta: 0.0,
        coupling: Coupling::Glm3Corrected,
    }
}

fn main() {
    let d = 64;
    let sizes = [512usize, 1024, 2048, 4096];
    let b = Bencher { min_samples: 3, max_samples: 6, target_time: 2.0, warmup: 1 };

    let mut fwd = Table::new(
        "Figure 1a — forward speedup over FlashAttention (×)",
        &["n", "hyper", "lev+hyper", "kmeans+hyper", "kmedian+hyper"],
    );
    let mut bwd = Table::new(
        "Figure 1b — forward+backward speedup over FlashAttention (×)",
        &["n", "hyper", "lev+hyper", "kmeans+hyper", "kmedian+hyper"],
    );

    for &n in &sizes {
        let (q, k, v) = qkv(n, d, n as u64);
        let inp = AttentionInputs::new(&q, &k, &v);
        let hyper_cfg = HyperConfig { block_size: 64, sample_size: 16, ..Default::default() };

        let t_flash = b.time("flash", || black_box(flash_attention(&inp))).median();
        let t_hyper =
            b.time("hyper", || black_box(hyper_attention(&inp, &hyper_cfg, None))).median();
        let t_lev = b
            .time("lev", || {
                black_box(prescored_hyper_attention(
                    &inp,
                    &prescored_cfg(Method::Leverage { exact: false }, n),
                ))
            })
            .median();
        let t_km = b
            .time("kmeans", || {
                black_box(prescored_hyper_attention(&inp, &prescored_cfg(Method::KMeans, n)))
            })
            .median();
        let t_kmed = b
            .time("kmedian", || {
                black_box(prescored_hyper_attention(&inp, &prescored_cfg(Method::KMedian, n)))
            })
            .median();
        fwd.row(vec![
            n.to_string(),
            f(t_flash / t_hyper, 2),
            f(t_flash / t_lev, 2),
            f(t_flash / t_km, 2),
            f(t_flash / t_kmed, 2),
        ]);

        // Forward+backward: flash fwd + exact backward vs hyper fwd +
        // sparse backward over the blockwise support (the "standard
        // HyperAttention pipeline" for the backward pass).
        let mut rng = Rng::new(n as u64 + 9);
        let dout = Matrix::randn(n, d, 1.0, &mut rng);
        let support: Vec<Vec<usize>> = {
            // blockwise support: 64 keys per query (its own block)
            (0..n).map(|i| ((i / 64) * 64..((i / 64) * 64 + 64).min(n)).collect()).collect()
        };
        let t_flash_fb = b
            .time("flash-fb", || {
                let o = flash_attention(&inp);
                black_box(exact_attention_backward(&inp, &dout));
                black_box(o)
            })
            .median();
        let fb = |fwd_fn: &dyn Fn() -> Matrix| -> f64 {
            b.time("x-fb", || {
                let o = fwd_fn();
                black_box(sparse_attention_backward(&inp, &dout, &support));
                black_box(o)
            })
            .median()
        };
        let t_hyper_fb = fb(&|| hyper_attention(&inp, &hyper_cfg, None));
        let t_lev_fb = fb(&|| {
            prescored_hyper_attention(&inp, &prescored_cfg(Method::Leverage { exact: false }, n)).0
        });
        let t_km_fb = fb(&|| prescored_hyper_attention(&inp, &prescored_cfg(Method::KMeans, n)).0);
        let t_kmed_fb =
            fb(&|| prescored_hyper_attention(&inp, &prescored_cfg(Method::KMedian, n)).0);
        bwd.row(vec![
            n.to_string(),
            f(t_flash_fb / t_hyper_fb, 2),
            f(t_flash_fb / t_lev_fb, 2),
            f(t_flash_fb / t_km_fb, 2),
            f(t_flash_fb / t_kmed_fb, 2),
        ]);
    }
    fwd.print();
    bwd.print();
    println!("\npaper shape: speedups grow with n; hyper >= lev+hyper >= kmeans/kmedian+hyper.");
}
