//! Figure 1 (a: forward, b: forward+backward): single-layer speedup over
//! FlashAttention for HyperAttention and the pre-scored variants, as a
//! function of sequence length.
//!
//! Paper shape to reproduce: all Hyper-based methods overtake FlashAttention
//! at long n (speedup grows with n); pre-scored variants track plain
//! HyperAttention with a small overhead gap (the O(n·d) pre-scoring cost),
//! with Lev+Hyper scaling best among the pre-scored ones.
//!
//! The kernel sweep is a list of declarative [`AttentionSpec`] strings —
//! adding a method to the figure means adding a spec, not a match arm.

use prescored::attention::backward::{exact_attention_backward, sparse_attention_backward};
use prescored::attention::{flash_attention, AttentionInputs, AttentionSpec};
use prescored::linalg::Matrix;
use prescored::util::bench::{black_box, f, Bencher, Table};
use prescored::util::rng::Rng;

fn qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    (
        Matrix::randn(n, d, 1.0, &mut rng),
        Matrix::randn(n, d, 1.0, &mut rng),
        Matrix::randn(n, d, 1.0, &mut rng),
    )
}

/// The Figure 1 kernel sweep at sequence length n (top_k = n/4, 3 Lloyd
/// iterations — the paper's speed-benchmark settings).
fn kernel_specs(n: usize) -> Vec<(&'static str, AttentionSpec)> {
    let parse = |s: &str| AttentionSpec::parse(s).unwrap();
    vec![
        ("hyper", parse("hyper:sample=16")),
        ("lev+hyper", parse(&format!("prescored:leverage,top_k={},iters=3,sample=16", n / 4))),
        ("kmeans+hyper", parse(&format!("prescored:kmeans,top_k={},iters=3,sample=16", n / 4))),
        (
            "kmedian+hyper",
            parse(&format!("prescored:kmedian,top_k={},iters=3,sample=16", n / 4)),
        ),
    ]
}

fn main() {
    let d = 64;
    let sizes = [512usize, 1024, 2048, 4096];
    let b = Bencher { min_samples: 3, max_samples: 6, target_time: 2.0, warmup: 1 };

    // Column headers follow the spec list, so adding a kernel to the sweep
    // extends the tables automatically.
    let mut headers = vec!["n"];
    let spec_names: Vec<&'static str> =
        kernel_specs(sizes[0]).into_iter().map(|(name, _)| name).collect();
    headers.extend(&spec_names);
    let mut fwd =
        Table::new("Figure 1a — forward speedup over FlashAttention (×)", &headers);
    let mut bwd = Table::new(
        "Figure 1b — forward+backward speedup over FlashAttention (×)",
        &headers,
    );

    for &n in &sizes {
        let (q, k, v) = qkv(n, d, n as u64);
        let inp = AttentionInputs::new(&q, &k, &v);
        let backends: Vec<_> =
            kernel_specs(n).into_iter().map(|(name, spec)| (name, spec.build())).collect();

        let t_flash = b.time("flash", || black_box(flash_attention(&inp))).median();
        let mut row = vec![n.to_string()];
        for (name, backend) in &backends {
            let t = b.time(name, || black_box(backend.forward(&inp))).median();
            row.push(f(t_flash / t, 2));
        }
        fwd.row(row);

        // Forward+backward: flash fwd + exact backward vs hyper fwd +
        // sparse backward over the blockwise support (the "standard
        // HyperAttention pipeline" for the backward pass).
        let mut rng = Rng::new(n as u64 + 9);
        let dout = Matrix::randn(n, d, 1.0, &mut rng);
        let support: Vec<Vec<usize>> = {
            // blockwise support: 64 keys per query (its own block)
            (0..n).map(|i| ((i / 64) * 64..((i / 64) * 64 + 64).min(n)).collect()).collect()
        };
        let t_flash_fb = b
            .time("flash-fb", || {
                let o = flash_attention(&inp);
                black_box(exact_attention_backward(&inp, &dout));
                black_box(o)
            })
            .median();
        let mut row = vec![n.to_string()];
        for (name, backend) in &backends {
            let t = b
                .time(name, || {
                    let o = backend.forward(&inp).out;
                    black_box(sparse_attention_backward(&inp, &dout, &support));
                    black_box(o)
                })
                .median();
            row.push(f(t_flash_fb / t, 2));
        }
        bwd.row(row);
    }
    fwd.print();
    bwd.print();
    println!("\npaper shape: speedups grow with n; hyper >= lev+hyper >= kmeans/kmedian+hyper.");
}
