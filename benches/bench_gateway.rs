//! Gateway serving bench: streamed tokens/sec and time-to-first-token
//! (TTFT) through the full wire path — TCP connect, HTTP POST, SSE stream —
//! as concurrent client count grows.
//!
//! The serving claim under test: continuous batching means aggregate
//! streamed throughput does not collapse as clients pile on — decode
//! rounds interleave many sessions across executor workers, so 32
//! concurrent SSE streams move at least as many tokens/sec as one.
//!
//! Emits `BENCH_gateway.json` at the repo root: `ttft_ms_p50` and
//! `tokens_per_s` keyed by client count.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_GATEWAY_CLIENTS` — comma list, default `1,8,32`
//! * `PALLAS_GATEWAY_CONTEXT` — context tokens per request, default 32
//! * `PALLAS_GATEWAY_NEW`     — generated tokens per request, default 16
//! * `PALLAS_GATEWAY_JSON`    — output path override (CI smoke points it
//!   at a scratch file so real baselines aren't clobbered)
//! * `PALLAS_GATEWAY_ASSERT`  — when `1`, exit non-zero unless throughput
//!   at the largest client count ≥ throughput at the smallest

use prescored::config::ServingConfig;
use prescored::gateway::{Gateway, GatewayConfig};
use prescored::model::{Transformer, TransformerConfig};
use prescored::server::ScoringServer;
use prescored::util::bench::{env_list, env_usize, f, Table};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const SPEC: &str = "prescored:kmeans,top_k=12,block=16,sample=4";

fn start_gateway(max_seq: usize, kv_blocks: usize, workers: usize) -> Gateway {
    let tcfg = TransformerConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        max_seq,
    };
    let cfg = ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        variant: "exact".into(),
        max_seq,
        attention_spec: SPEC.into(),
        executor_workers: workers,
        kv_blocks,
        ..Default::default()
    };
    let server = ScoringServer::start_with_model(cfg, Transformer::random(tcfg, 61))
        .expect("server start");
    Gateway::start(GatewayConfig::default(), server).expect("gateway start")
}

/// One wire client: POST a generate request, stream the SSE response, and
/// return (ttft, token events, saw done). Contexts are generated
/// server-side via the `corpus_len` wire field.
fn run_client(addr: SocketAddr, context: usize, n_new: usize, seed: usize) -> (f64, usize, bool) {
    let body = format!(
        "{{\"corpus_len\": {context}, \"corpus_seed\": {seed}, \"generate\": {n_new}}}"
    );
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).expect("timeout");
    let t0 = Instant::now();
    stream
        .write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nHost: gw\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .expect("write request");

    let mut raw: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    let mut ttft_ms = f64::NAN;
    let mut scanned = 0usize;
    let mut tokens = 0usize;
    let mut done = false;
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break,
        };
        raw.extend_from_slice(&chunk[..n]);
        // Count event markers in the newly arrived window (re-scan a few
        // bytes of overlap so a marker split across reads still counts).
        let start = scanned.saturating_sub(16);
        let window = &raw[start..];
        let fresh_tokens = count_occurrences(window, b"event: token")
            - count_occurrences(&raw[start..scanned], b"event: token");
        if fresh_tokens > 0 && tokens == 0 {
            ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
        }
        tokens += fresh_tokens;
        if count_occurrences(window, b"event: done")
            > count_occurrences(&raw[start..scanned], b"event: done")
        {
            done = true;
        }
        scanned = raw.len();
    }
    (ttft_ms, tokens, done)
}

fn count_occurrences(haystack: &[u8], needle: &[u8]) -> usize {
    if haystack.len() < needle.len() {
        return 0;
    }
    haystack.windows(needle.len()).filter(|w| w == &needle).count()
}

fn main() {
    let clients_axis = env_list("PALLAS_GATEWAY_CLIENTS", &[1usize, 8, 32]);
    let context = env_usize("PALLAS_GATEWAY_CONTEXT", 32);
    let n_new = env_usize("PALLAS_GATEWAY_NEW", 16);
    let assert_scaling = std::env::var("PALLAS_GATEWAY_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_GATEWAY_JSON").unwrap_or_else(|_| "BENCH_gateway.json".into());

    let max_seq = context + n_new + 8;
    let max_clients = clients_axis.iter().copied().max().unwrap_or(1);
    let pages = (context + n_new) / 16 + 2;
    let kv_blocks = (max_clients * pages).max(512);
    println!(
        "== gateway streaming: clients {clients_axis:?}, context {context}, {n_new} new =="
    );

    let mut table =
        Table::new("gateway streaming", &["clients", "ttft p50 (ms)", "tokens/s"]);
    let mut results: Vec<(usize, f64, f64)> = Vec::new();
    for &n_clients in &clients_axis {
        // Fresh server + gateway per concurrency level: stats and KV state
        // start clean, so levels are comparable.
        let gw = start_gateway(max_seq, kv_blocks, 4);
        let addr = gw.addr();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                std::thread::spawn(move || run_client(addr, context, n_new, i))
            })
            .collect();
        let mut ttfts = Vec::new();
        let mut total_tokens = 0usize;
        for h in handles {
            let (ttft, tokens, done) = h.join().expect("client thread");
            assert!(done, "stream must end with a done event");
            assert_eq!(tokens, n_new, "every client streams every token");
            ttfts.push(ttft);
            total_tokens += tokens;
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
        let stats = gw.shutdown();
        assert_eq!(stats.completed, n_clients, "all streams complete");
        assert_eq!(
            stats.kv_pages_acquired, stats.kv_pages_released,
            "bench run must balance page accounting"
        );
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let ttft_p50 = ttfts[ttfts.len() / 2];
        let tokens_per_s = total_tokens as f64 / wall_s;
        table.row(vec![n_clients.to_string(), f(ttft_p50, 2), f(tokens_per_s, 1)]);
        results.push((n_clients, ttft_p50, tokens_per_s));
    }
    table.print();

    // JSON emission.
    let mut fields = Vec::new();
    for (clients, ttft, tps) in &results {
        fields.push(format!(
            "    \"{clients}\": {{\"ttft_ms_p50\": {ttft:.3}, \"tokens_per_s\": {tps:.1}}}"
        ));
    }
    let json = format!(
        "{{\n  \"context\": {context},\n  \"new_tokens\": {n_new},\n  \"by_clients\": {{\n{}\n  }}\n}}\n",
        fields.join(",\n")
    );
    std::fs::write(&json_path, json).expect("writing BENCH_gateway.json");
    println!("wrote {json_path}");

    if assert_scaling {
        let (c0, _, tps0) = results[0];
        let (c1, _, tps1) = results[results.len() - 1];
        if results.len() < 2 {
            println!("PALLAS_GATEWAY_ASSERT: need at least two client counts");
        } else if tps1 < tps0 {
            eprintln!(
                "ASSERT FAILED: {c1}-client throughput {tps1:.1} tok/s fell below \
                 {c0}-client throughput {tps0:.1} tok/s — continuous batching must \
                 not collapse under concurrency"
            );
            std::process::exit(1);
        } else {
            println!(
                "assert ok: {c1}-client {tps1:.1} tok/s >= {c0}-client {tps0:.1} tok/s"
            );
        }
    }
}
