//! Table 2 — zero-shot substitution ViT with K-means pre-scoring
//! (accuracy, higher is better) + Table 6 — LevAttention / ℓ2-norm ViT
//! baselines (Appendix E).
//!
//! Paper shape: accuracy increases monotonically with num_sample toward the
//! base model; K-means selection beats leverage-based selection at the same
//! key budget; the ℓ2-norm baseline collapses.
//!
//! Every configuration is a declarative [`AttentionSpec`] string — the grid
//! is a list of specs, not hand-written match arms.

use prescored::attention::AttentionSpec;
use prescored::data::images::ImageConfig;
use prescored::exp::{vit_accuracy, vit_eval_data};
use prescored::model::{Vit, VitConfig, WeightStore};
use prescored::util::bench::{f, Table};
use std::path::Path;

fn spec(s: &str) -> AttentionSpec {
    AttentionSpec::parse(s).unwrap()
}

fn main() {
    let weights = Path::new("artifacts/vit_weights.bin");
    let vit = if weights.exists() {
        let ws = WeightStore::load(weights).unwrap();
        Vit::from_weights(&ws, VitConfig::default())
    } else {
        eprintln!("vit_weights.bin missing — using random weights");
        Vit::random(VitConfig::default(), 1)
    };
    let img_cfg = ImageConfig::default();
    let data = vit_eval_data(&img_cfg, 300, 77);

    let base = vit_accuracy(&vit, &data, &spec("exact"));
    let mut t2 = Table::new(
        "Table 2 — zero-shot ViT substitution, K-means pre-scoring (top-1 acc %)",
        &["Configuration", "Acc."],
    );
    t2.row(vec!["Base model".into(), f(base * 100.0, 2)]);
    // ViT seq is 65 here (64 patches + cls); the paper's 32..128 grid maps
    // onto proportional budgets of our sequence.
    for (c, s) in [(4usize, 8usize), (4, 16), (4, 24), (4, 32), (6, 32)] {
        let acc = vit_accuracy(
            &vit,
            &data,
            &spec(&format!("restricted:balanced,clusters={c},samples={s},seed=3")),
        );
        t2.row(vec![format!("num_cluster={c}, num_sample={s}"), f(acc * 100.0, 2)]);
    }
    t2.print();

    let mut t6 = Table::new(
        "Table 6 — LevAttention ViT baselines (top-1 acc %)",
        &["Model", "Acc."],
    );
    t6.row(vec!["softmax (base)".into(), f(base * 100.0, 2)]);
    for k in [8usize, 16, 32] {
        let lev =
            vit_accuracy(&vit, &data, &spec(&format!("restricted:leverage-exact,top_k={k}")));
        t6.row(vec![format!("LevAttn, top-{k}"), f(lev * 100.0, 2)]);
        let l2 = vit_accuracy(&vit, &data, &spec(&format!("restricted:l2norm,top_k={k}")));
        t6.row(vec![format!("ℓ2 norm, top-{k}"), f(l2 * 100.0, 2)]);
    }
    // the key head-to-head at the paper's headline budget
    let km32 =
        vit_accuracy(&vit, &data, &spec("restricted:balanced,clusters=4,samples=32,seed=3"));
    let lev32 = vit_accuracy(&vit, &data, &spec("restricted:leverage-exact,top_k=32"));
    t6.print();
    println!(
        "\nhead-to-head @ budget 32: kmeans {:.2}% vs leverage {:.2}%  (paper: 84.46% vs 77.17%)",
        km32 * 100.0,
        lev32 * 100.0
    );
}
