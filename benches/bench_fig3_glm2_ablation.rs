//! Figure 3 / Appendix F — the GLM2 coupling-artifact ablation.
//!
//! Runs the same top-k sweep under the artifact-laden GLM2 coupling
//! (zeroed keys/values, global-n residual scaling, block–residual double
//! counting) and the corrected GLM3 coupling. Shape to reproduce: GLM2
//! shows the unstable / U-shaped curve; GLM3 is stable and ~monotone.

use prescored::attention::Coupling;
use prescored::exp::{eval_docs, ppl_over, prescored_spec};
use prescored::model::{Transformer, TransformerConfig, WeightStore};
use prescored::prescore::Method;
use prescored::util::bench::{f, Table};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    let model = if dir.join("weights.bin").exists() {
        let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
        Transformer::from_weights(&ws, TransformerConfig::default())
    } else {
        eprintln!("artifacts missing — using random weights");
        Transformer::random(TransformerConfig::default(), 1)
    };
    let docs = eval_docs(512, 256, 3, true, 33_000);

    let mut t = Table::new(
        "Figure 3 — coupling ablation: GLM2 artifacts vs GLM3 corrected (PPL)",
        &["Top K", "GLM2 (zeroing+n-scale+overlap)", "GLM3 (bias-mask+|S|-scale+exclusion)"],
    );
    for &k in &[8usize, 32, 64, 128, 192] {
        let glm2 = ppl_over(
            &model,
            &prescored_spec(Method::KMeans, k, 16, Coupling::Glm2Artifact, true),
            &docs,
        );
        let glm3 = ppl_over(
            &model,
            &prescored_spec(Method::KMeans, k, 16, Coupling::Glm3Corrected, true),
            &docs,
        );
        t.row(vec![k.to_string(), f(glm2, 3), f(glm3, 3)]);
    }
    t.print();
    println!("\npaper shape: the corrected coupling dominates and is stable across k;");
    println!("the GLM2 artifacts distort the efficiency–accuracy relationship.");
}
