//! Table 1 — disentangling pre-scoring from blockwise optimization.
//!
//! | Method          | Pre-score | Blockwise Opt. | PPL  (paper: 5.6 / 17.54 / 13.41 / 10.38 / 9.53)
//!
//! Mapping: "Blockwise Opt." toggles the Gray-code bucket *sorting* of the
//! LSH (off ⇒ 1-bit hash ≈ unsorted blocks); FlashAttention is the exact
//! reference. Shape to reproduce: exact < prescored+opt < prescored
//! < hyper+opt < hyper.

use prescored::attention::{AttentionSpec, Coupling};
use prescored::exp::{eval_docs, hyper_spec, ppl_over, prescored_spec};
use prescored::model::{Transformer, TransformerConfig, WeightStore};
use prescored::prescore::Method;
use prescored::util::bench::{f, Table};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    let model = if dir.join("weights.bin").exists() {
        let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
        Transformer::from_weights(&ws, TransformerConfig::default())
    } else {
        eprintln!("artifacts missing — using random weights (shapes only)");
        Transformer::random(TransformerConfig::default(), 1)
    };
    let docs = eval_docs(512, 256, 4, true, 20_000);
    let budget = 64; // retained keys for the pre-scored rows

    // Kernel sweep: each row is a declarative spec — no hand-written match
    // arms; add rows by adding specs.
    let rows: Vec<(&str, bool, bool, AttentionSpec)> = vec![
        ("FlashAttention", false, false, AttentionSpec::parse("flash").unwrap()),
        ("HyperAttention", false, false, hyper_spec(64, false)),
        ("HyperAttention", false, true, hyper_spec(64, true)),
        (
            "K-means+Hyper",
            true,
            false,
            prescored_spec(Method::KMeans, budget, 16, Coupling::Glm3Corrected, false),
        ),
        (
            "K-means+Hyper",
            true,
            true,
            prescored_spec(Method::KMeans, budget, 16, Coupling::Glm3Corrected, true),
        ),
    ];

    let mut t = Table::new(
        "Table 1 — pre-scoring vs blockwise optimization (PPL, lower is better)",
        &["Method", "Pre-score", "Blockwise Opt.", "PPL"],
    );
    for (name, ps, bw, spec) in rows {
        let ppl = ppl_over(&model, &spec, &docs);
        t.row(vec![name.into(), ps.to_string(), bw.to_string(), f(ppl, 3)]);
    }
    t.print();
    println!("\npaper shape: flash lowest; pre-scoring improves hyper at both settings;");
    println!("blockwise sorting gives a complementary gain.");
}
