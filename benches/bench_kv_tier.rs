//! Tiered KV memory: quantized cached pages × disk-spill tier.
//!
//! Three claims from the tiering PR, measured end to end through the
//! serving engine:
//!
//! 1. **Capacity** — at an equal prefix-pool page budget, `[cache]
//!    kv_dtype = f16` caches ~2× and `int8` ~4× the tokens of `f32`
//!    (pages pack 32 / 64 tokens instead of 16).
//! 2. **Warm-disk beats cold** — re-admitting an LRU-evicted prefix from
//!    the spill file and resuming over the suffix is faster than a full
//!    cold prefill of the same request.
//! 3. **PPL gate** — decoding from a quantized session stays within a
//!    pinned NLL delta of the full-precision reference (the relaxed
//!    exactness contract; f32 stays bitwise).
//!
//! Emits `BENCH_kvtier.json` at the repo root.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_TIER_CONTEXT` — prompt length for the latency part, default 256
//! * `PALLAS_TIER_NEW`     — generated tokens per timed request, default 4
//! * `PALLAS_TIER_REPS`    — timing repetitions, default 3
//! * `PALLAS_TIER_PROMPTS` — prompts thrown at the capacity pool, default 20
//! * `PALLAS_TIER_POOL`    — capacity-part pool budget in pages, default 8
//! * `PALLAS_TIER_D`       — d_model, default 32
//! * `PALLAS_TIER_JSON`    — output path override (CI smoke → scratch file)
//! * `PALLAS_TIER_ASSERT`  — when `1`, exit non-zero unless int8 caches
//!   ≥ 2× the f32 tokens at an equal pool, warm-disk beats cold for every
//!   dtype, and the PPL deltas hold (the CI gate)

use prescored::attention::AttnPolicy;
use prescored::config::ServingConfig;
use prescored::coordinator::{KvDtype, Request};
use prescored::data::corpus;
use prescored::linalg::Matrix;
use prescored::model::{Transformer, TransformerConfig};
use prescored::parallel;
use prescored::server::ScoringServer;
use prescored::util::bench::{env_usize, f};
use std::time::Instant;

const DTYPES: [KvDtype; 3] = [KvDtype::F32, KvDtype::F16, KvDtype::Int8];
const VOCAB: u32 = 64;
/// Pinned NLL-delta budgets (nats) per dtype, same order as [`DTYPES`]:
/// f32 is bitwise (suffix-stable resume at thread width 1), f16/int8 get
/// the relaxed-exactness budget the tiering PR pins.
const PPL_BUDGETS: [f64; 3] = [1e-6, 0.02, 0.15];

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite timing"));
    v[v.len() / 2]
}

fn model_cfg(d_model: usize, max_seq: usize) -> TransformerConfig {
    TransformerConfig { vocab: VOCAB as usize, d_model, n_layers: 2, n_heads: 2, max_seq }
}

fn serving_cfg(dtype: KvDtype, max_seq: usize, pool_pages: usize) -> ServingConfig {
    ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        variant: "exact".into(),
        attention_spec: "exact".into(),
        max_seq,
        executor_workers: 1,
        kv_blocks: 4 * max_seq.div_ceil(16),
        prefix_cache_blocks: pool_pages,
        prefix_min_tokens: 16,
        kv_dtype: dtype.as_str().into(),
        shed_high_watermark: 2.0,
        shed_queue_high: usize::MAX,
        ..Default::default()
    }
}

fn request(id: u64, tokens: Vec<u32>, generate: usize) -> Request {
    let mut req = Request::scoring(id, tokens);
    req.generate = generate;
    req
}

/// Per-token NLL of `targets[i]` from logits row `i` (log-softmax).
fn nll_rows(logits: &Matrix, targets: &[u32]) -> Vec<f32> {
    targets
        .iter()
        .enumerate()
        .map(|(i, &t)| prescored::model::transformer::nll_entry(logits.row(i), t))
        .collect()
}

fn main() {
    let context = env_usize("PALLAS_TIER_CONTEXT", 256);
    let n_new = env_usize("PALLAS_TIER_NEW", 4);
    let reps = env_usize("PALLAS_TIER_REPS", 3).max(1);
    let n_prompts = env_usize("PALLAS_TIER_PROMPTS", 20);
    let cap_pool = env_usize("PALLAS_TIER_POOL", 8);
    let d_model = env_usize("PALLAS_TIER_D", 32);
    let assert_gate = std::env::var("PALLAS_TIER_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_TIER_JSON").unwrap_or_else(|_| "BENCH_kvtier.json".into());
    let max_seq = context + 64;

    println!(
        "== tiered KV: capacity × dtype @ pool {cap_pool} pages, warm-disk vs cold @ \
         context {context}, d_model {d_model} =="
    );

    // Part 1 — cached tokens at an equal page budget. The same prompt set
    // flows through a server per dtype; resident tokens come from the
    // engine's own accounting after the pool has churned.
    let cap_prompt_len = 32usize;
    let mut capacity = Vec::new();
    for dtype in DTYPES {
        let cfg = serving_cfg(dtype, max_seq, cap_pool);
        let model = Transformer::random(model_cfg(d_model, max_seq), 0x7157);
        let server = ScoringServer::start_with_model(cfg, model).expect("server start");
        for i in 0..n_prompts {
            let tokens = corpus::generate(VOCAB, cap_prompt_len, 4000 + i as u64);
            let resp = server.submit(request(i as u64, tokens, 1)).recv().expect("response");
            assert!(resp.error.is_none(), "capacity prompt {i}: {:?}", resp.error);
        }
        let stats = server.shutdown();
        capacity.push(stats.prefix_cached_tokens);
        println!(
            "capacity | {:>4} | pool {cap_pool:>3} pages | {:>6} resident cached tokens",
            dtype.as_str(),
            stats.prefix_cached_tokens
        );
    }

    // Part 2 — warm-disk re-admit vs cold recompute. A one-prompt pool plus
    // a spill file: each rep evicts the target subtree to the disk tier
    // with a filler prompt, then times the re-admitted request; cold reps
    // pay the full prefill on a fresh server with an empty cache.
    let prompt = corpus::generate(VOCAB, context, 0x5ca1e);
    let mut extended = prompt.clone();
    extended.extend(corpus::generate(VOCAB, 8, 0x5ca1f));
    let mut latency = Vec::new();
    for dtype in DTYPES {
        let spill = std::env::temp_dir()
            .join(format!("bench_kvtier_{}_{}.spill", std::process::id(), dtype.as_str()));
        let pool = dtype.pages_for(context + 16 + n_new) + 1;
        let mut cfg = serving_cfg(dtype, max_seq, pool);
        cfg.prefix_spill_path = spill.display().to_string();
        let model = Transformer::random(model_cfg(d_model, max_seq), 0x7157);
        let server = ScoringServer::start_with_model(cfg, model).expect("server start");

        // Seed the cache with the target prompt.
        let resp = server.submit(request(9000, prompt.clone(), 1)).recv().expect("seed");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let mut warm_samples = Vec::new();
        for rep in 0..reps {
            // The filler evicts the resident target subtree to the disk tier.
            let filler = corpus::generate(VOCAB, context, 6000 + rep as u64);
            let resp =
                server.submit(request(9100 + rep as u64, filler, 1)).recv().expect("filler");
            assert!(resp.error.is_none(), "{:?}", resp.error);
            let t0 = Instant::now();
            let resp = server
                .submit(request(9200 + rep as u64, extended.clone(), n_new))
                .recv()
                .expect("warm");
            assert!(resp.error.is_none(), "{:?}", resp.error);
            warm_samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let stats = server.shutdown();
        assert!(
            stats.tier_readmits >= 1,
            "{}: the timed requests must actually re-admit from disk ({} readmits)",
            dtype.as_str(),
            stats.tier_readmits
        );
        let _ = std::fs::remove_file(&spill);

        // Cold reference: same request, fresh server, nothing cached.
        let mut cold_samples = Vec::new();
        for rep in 0..reps {
            let cfg = serving_cfg(dtype, max_seq, pool);
            let model = Transformer::random(model_cfg(d_model, max_seq), 0x7157);
            let server = ScoringServer::start_with_model(cfg, model).expect("server start");
            let t0 = Instant::now();
            let resp = server
                .submit(request(9300 + rep as u64, extended.clone(), n_new))
                .recv()
                .expect("cold");
            assert!(resp.error.is_none(), "{:?}", resp.error);
            cold_samples.push(t0.elapsed().as_secs_f64() * 1e3);
            server.shutdown();
        }
        let (cold_ms, warm_ms) = (median(cold_samples), median(warm_samples));
        latency.push((cold_ms, warm_ms));
        println!(
            "latency  | {:>4} | cold {:>9} ms | warm-disk {:>9} ms | speedup {:>6}x \
             ({} spills, {} readmits)",
            dtype.as_str(),
            f(cold_ms, 2),
            f(warm_ms, 2),
            f(cold_ms / warm_ms.max(1e-9), 2),
            stats.tier_spills,
            stats.tier_readmits,
        );
    }

    // Part 3 — the PPL gate (Fig. 2 harness style: per-token NLL over a
    // held-out suffix). Decode/resume from a quantized session vs the
    // full-precision prefill reference; serial pool so f32 stays bitwise.
    let model = Transformer::random(model_cfg(d_model, max_seq), 0x7157);
    let policy = AttnPolicy::parse("exact").expect("policy");
    let tokens = corpus::generate(VOCAB, context.min(192), 0xf19);
    let split = tokens.len() / 2;
    let ref_nll = parallel::with_threads(1, || model.nll_policy(&tokens, &policy));
    let ref_mean = ref_nll[split..].iter().map(|&v| v as f64).sum::<f64>()
        / (tokens.len() - 1 - split) as f64;
    let mut ppl = Vec::new();
    for dtype in DTYPES {
        let quant_mean = parallel::with_threads(1, || {
            let (_, mut sess) =
                model.begin_decode_dtype(&tokens[..split], &policy, dtype).expect("prefill");
            let logits = model.resume_decode(&mut sess, &tokens[split..], &policy);
            let nll = nll_rows(&logits, &tokens[split + 1..]);
            nll.iter().map(|&v| v as f64).sum::<f64>() / nll.len() as f64
        });
        let delta = quant_mean - ref_mean;
        ppl.push((quant_mean, delta));
        println!(
            "ppl gate | {:>4} | ref {} | quant {} | delta {:+.6} nats",
            dtype.as_str(),
            f(ref_mean, 4),
            f(quant_mean, 4),
            delta,
        );
    }

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"context\": {context},\n  \"d_model\": {d_model},\n  \"pool_pages\": {cap_pool},\n"
    ));
    json.push_str("  \"capacity_tokens\": {");
    for (i, dtype) in DTYPES.iter().enumerate() {
        let sep = if i + 1 < DTYPES.len() { ", " } else { "" };
        json.push_str(&format!("\"{}\": {}{sep}", dtype.as_str(), capacity[i]));
    }
    json.push_str("},\n  \"latency_ms\": {");
    for (i, dtype) in DTYPES.iter().enumerate() {
        let (cold, warm) = latency[i];
        let sep = if i + 1 < DTYPES.len() { ", " } else { "" };
        json.push_str(&format!(
            "\"{}\": {{\"cold\": {cold:.4}, \"warm_disk\": {warm:.4}, \"speedup\": {:.4}}}{sep}",
            dtype.as_str(),
            cold / warm.max(1e-9)
        ));
    }
    json.push_str("},\n  \"ppl_nats\": {");
    for (i, dtype) in DTYPES.iter().enumerate() {
        let (nll, delta) = ppl[i];
        let sep = if i + 1 < DTYPES.len() { ", " } else { "" };
        json.push_str(&format!(
            "\"{}\": {{\"ref\": {ref_mean:.6}, \"nll\": {nll:.6}, \"delta\": {delta:.6}}}{sep}",
            dtype.as_str()
        ));
    }
    json.push_str("},\n  \"spec\": \"exact\"\n}\n");
    std::fs::write(&json_path, json).expect("writing BENCH_kvtier.json");
    println!("wrote {json_path}");

    if assert_gate {
        let mut failed = false;
        // int8 must cache at least 2× the f32 tokens at an equal pool (the
        // page-packing claim is 4×; 2× leaves headroom for radix-segment
        // fragmentation at page boundaries).
        if capacity[2] < 2 * capacity[0] {
            eprintln!(
                "TIER CAPACITY REGRESSION: int8 cached {} tokens vs f32 {} at an equal \
                 {cap_pool}-page pool (< 2x)",
                capacity[2], capacity[0]
            );
            failed = true;
        }
        for (i, dtype) in DTYPES.iter().enumerate() {
            let (cold, warm) = latency[i];
            if warm >= cold {
                eprintln!(
                    "TIER LATENCY REGRESSION: {} warm-disk {warm:.3} ms >= cold {cold:.3} ms",
                    dtype.as_str()
                );
                failed = true;
            }
        }
        for (i, dtype) in DTYPES.iter().enumerate() {
            if ppl[i].1.abs() > PPL_BUDGETS[i] {
                eprintln!(
                    "TIER PPL REGRESSION: {} NLL delta {:+.6} nats exceeds budget {}",
                    dtype.as_str(),
                    ppl[i].1,
                    PPL_BUDGETS[i]
                );
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!("tier assertions passed (capacity, warm-disk-beats-cold, ppl gate)");
    }
}
