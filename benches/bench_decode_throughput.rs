//! Decode-path throughput: tokens/sec of the incremental `decode_step`
//! engine vs context length, thread count, and execution engine.
//!
//! The serving claim under test (§3.1 + the ROADMAP north star): with the
//! prefill selection cached, a decode step for `prescored:*`/`restricted:*`
//! specs costs selection-sized work, so per-token cost grows *sub-linearly*
//! in context length, while dense kernels (`flash`) stay O(n) per token —
//! and on sub-millisecond steps the persistent channel-fed pool beats the
//! old scoped-thread fork-join engine at the same width (spawn overhead is
//! the bottleneck there, not compute).
//!
//! Emits `BENCH_decode.json` at the repo root:
//! `tokens_per_s[spec][context][threads]` plus the fork-join-vs-pool
//! comparison at the largest context.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_DECODE_CONTEXTS` — comma list, default `2048,8192,32768`
//! * `PALLAS_DECODE_STEPS`    — decode steps per measurement, default 32
//! * `PALLAS_DECODE_D`       — head dim, default 64
//! * `PALLAS_DECODE_JSON`    — output path override (the CI smoke run
//!   points it at a scratch file so real baselines aren't clobbered)

use prescored::attention::AttentionSpec;
use prescored::linalg::Matrix;
use prescored::parallel::{self, ExecMode};
use prescored::util::bench::{black_box, env_list, env_usize, f, Table};
use prescored::util::rng::Rng;
use std::time::Instant;

const SPECS: &[&str] = &[
    "flash",
    "hyper:block=32,sample=16,seed=3",
    "prescored:kmeans,top_k=64,refresh=16,block=32,iters=5",
    "restricted:l2norm,top_k=64",
];

fn env_contexts() -> Vec<usize> {
    env_list("PALLAS_DECODE_CONTEXTS", &[2048usize, 8192, 32768])
}

/// Stream `steps` tokens through the decode arm; returns tokens/sec.
fn decode_tokens_per_s(
    spec: &AttentionSpec,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    n0: usize,
    steps: usize,
) -> f64 {
    let backend = spec.build();
    let mut state = backend
        .begin_decode(&q.slice_rows(0, n0), &k.slice_rows(0, n0), 0)
        .expect("bench specs all have decode arms");
    let mut kc = k.slice_rows(0, n0);
    let mut vc = v.slice_rows(0, n0);
    let t0 = Instant::now();
    for t in n0..n0 + steps {
        kc.push_row(k.row(t));
        vc.push_row(v.row(t));
        black_box(backend.decode_step(&mut state, q.row(t), &kc, &vc, None));
    }
    steps as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

fn json_escape_key(s: &str) -> String {
    s.replace('"', "\\\"")
}

fn main() {
    let contexts = env_contexts();
    let steps = env_usize("PALLAS_DECODE_STEPS", 32);
    let d = env_usize("PALLAS_DECODE_D", 64);
    let pool_width = parallel::num_threads().max(2);
    // The persistent pool sizes itself from the *global* width; raise it so
    // the pool column is genuinely parallel even on narrow/PALLAS_THREADS=1
    // machines (with_threads below only picks the shard count per run).
    parallel::set_threads(pool_width);
    let thread_counts = [1usize, pool_width];
    println!(
        "== decode throughput: contexts {contexts:?}, {steps} steps, d={d}, \
         threads {{1, {pool_width}}} =="
    );

    // tokens_per_s[spec_idx][ctx_idx][thread_idx]
    let mut results = vec![vec![vec![0.0f64; thread_counts.len()]; contexts.len()]; SPECS.len()];
    for (ci, &n0) in contexts.iter().enumerate() {
        let mut rng = Rng::new(0xdec0de + n0 as u64);
        let total = n0 + steps;
        let q = Matrix::randn(total, d, 1.0, &mut rng);
        let k = Matrix::randn(total, d, 1.0, &mut rng);
        let v = Matrix::randn(total, d, 1.0, &mut rng);
        let mut table = Table::new(
            &format!("Decode tokens/sec @ context {n0}"),
            &["spec", "threads=1", &format!("threads={pool_width}")],
        );
        for (si, spec_str) in SPECS.iter().enumerate() {
            let spec = AttentionSpec::parse(spec_str).expect("valid spec");
            let mut row = vec![spec_str.to_string()];
            for (ti, &t) in thread_counts.iter().enumerate() {
                let tok_s = parallel::with_threads(t, || {
                    decode_tokens_per_s(&spec, &q, &k, &v, n0, steps)
                });
                results[si][ci][ti] = tok_s;
                row.push(f(tok_s, 1));
            }
            table.row(row);
        }
        table.print();
    }

    // Sub-linearity report: per-token cost growth factor across the sweep
    // (dense kernels ≈ context ratio; selection-restricted kernels ≪ it).
    if contexts.len() >= 2 {
        let first = contexts[0];
        let last = contexts[contexts.len() - 1];
        println!(
            "\nper-token cost growth, context {first} → {last} \
             (1.0 = flat; {:.0} = linear in context):",
            last as f64 / first as f64
        );
        for (si, spec_str) in SPECS.iter().enumerate() {
            let growth = results[si][0][0] / results[si][contexts.len() - 1][0].max(1e-12);
            println!("  {spec_str:<48} {:.2}x", growth);
        }
    }

    // Fork-join vs persistent pool on the sharded dense row at the largest
    // context — the spawn-overhead claim the pool upgrade exists for.
    let n0 = *contexts.last().expect("at least one context");
    let mut rng = Rng::new(0xf0f0 + n0 as u64);
    let total = n0 + steps;
    let q = Matrix::randn(total, d, 1.0, &mut rng);
    let k = Matrix::randn(total, d, 1.0, &mut rng);
    let v = Matrix::randn(total, d, 1.0, &mut rng);
    let flash = AttentionSpec::parse("flash").unwrap();
    let prev_mode = parallel::exec_mode();
    // Same global width (set above) for both engines — only dispatch differs.
    parallel::set_exec_mode(ExecMode::Persistent);
    let pool_tok_s = decode_tokens_per_s(&flash, &q, &k, &v, n0, steps);
    parallel::set_exec_mode(ExecMode::ForkJoin);
    let forkjoin_tok_s = decode_tokens_per_s(&flash, &q, &k, &v, n0, steps);
    parallel::set_exec_mode(prev_mode);
    println!(
        "\nflash decode @ {n0} ctx, {pool_width} threads: persistent pool {:.1} tok/s vs \
         fork-join {:.1} tok/s ({:.2}x)",
        pool_tok_s,
        forkjoin_tok_s,
        pool_tok_s / forkjoin_tok_s.max(1e-12)
    );

    // Machine-readable emission.
    let mut spec_entries: Vec<String> = Vec::new();
    for (si, spec_str) in SPECS.iter().enumerate() {
        let mut ctx_entries: Vec<String> = Vec::new();
        for (ci, &n0) in contexts.iter().enumerate() {
            let pairs: Vec<String> = thread_counts
                .iter()
                .enumerate()
                .map(|(ti, &t)| format!("\"{t}\": {:.3}", results[si][ci][ti]))
                .collect();
            ctx_entries.push(format!("\"{n0}\": {{{}}}", pairs.join(", ")));
        }
        spec_entries.push(format!(
            "    \"{}\": {{{}}}",
            json_escape_key(spec_str),
            ctx_entries.join(", ")
        ));
    }
    let json = format!(
        "{{\n  \"d\": {d},\n  \"steps\": {steps},\n  \"contexts\": [{}],\n  \
         \"pool_threads\": {pool_width},\n  \"tokens_per_s\": {{\n{}\n  }},\n  \
         \"forkjoin_vs_pool\": {{\"spec\": \"flash\", \"context\": {n0}, \
         \"threads\": {pool_width}, \"forkjoin_tok_s\": {forkjoin_tok_s:.3}, \
         \"pool_tok_s\": {pool_tok_s:.3}, \"pool_speedup\": {:.4}}}\n}}\n",
        contexts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", "),
        spec_entries.join(",\n"),
        pool_tok_s / forkjoin_tok_s.max(1e-12),
    );
    let out = std::env::var("PALLAS_DECODE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json").to_string()
    });
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
