//! §5.1 complexity ablation — pre-scoring overhead scaling.
//!
//! The paper argues the pre-scoring overhead is ≈ O(n·d) (clustering:
//! O(n·d·k·I) with k ≪ n; leverage: O(n·d·log d)). This bench measures the
//! standalone selection cost vs n and reports the empirical scaling
//! exponent, plus the mini-batch variant (Appendix H future work).

use prescored::linalg::Matrix;
use prescored::prescore::{prescore, Method, PreScoreConfig};
use prescored::util::bench::{black_box, f, Bencher, Table};
use prescored::util::rng::Rng;

fn main() {
    let d = 64;
    let sizes = [512usize, 1024, 2048, 4096, 8192];
    let b = Bencher { min_samples: 3, max_samples: 6, target_time: 1.0, warmup: 1 };
    let methods: Vec<(&str, Method)> = vec![
        ("kmeans", Method::KMeans),
        ("kmedian", Method::KMedian),
        ("leverage", Method::Leverage { exact: false }),
        ("minibatch", Method::MiniBatch { batch: 256 }),
    ];

    let mut t = Table::new(
        "Pre-scoring overhead vs n (ms) — paper: ≈O(n·d)",
        &["n", "kmeans", "kmedian", "leverage", "minibatch"],
    );
    let mut times: Vec<Vec<f64>> = vec![Vec::new(); methods.len()];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let mut row = vec![n.to_string()];
        for (mi, (_, m)) in methods.iter().enumerate() {
            let cfg = PreScoreConfig { method: *m, top_k: n / 4, max_iters: 5, ..Default::default() };
            let tm = b.time("ps", || black_box(prescore(&k, &cfg))).median();
            times[mi].push(tm);
            row.push(f(tm * 1e3, 2));
        }
        t.row(row);
    }
    t.print();

    println!("\nempirical scaling exponent (log-slope of time vs n; 1.0 = linear):");
    for (mi, (name, _)) in methods.iter().enumerate() {
        let first = times[mi][0];
        let last = *times[mi].last().unwrap();
        let slope = (last / first).log2() / ((sizes[sizes.len() - 1] as f64 / sizes[0] as f64).log2());
        println!("  {name:<10} {:.2}", slope);
    }
}
