//! §5.1 complexity ablation — pre-scoring overhead scaling, plus the
//! parallel-engine scaling sweep.
//!
//! Part 1 (paper): the pre-scoring overhead is ≈ O(n·d) (clustering:
//! O(n·d·k·I) with k ≪ n; leverage: O(n·d·log d)). This bench measures the
//! standalone selection cost vs n and reports the empirical scaling
//! exponent, plus the mini-batch variant (Appendix H future work).
//!
//! Part 2 (systems): sweep the work-pool width over `flash_attention` and
//! the end-to-end `prescored_hyper_attention` pipeline at n=8192, d=64,
//! verify the parallel outputs against the `threads=1` baseline, and emit a
//! machine-readable `BENCH_parallel.json` (threads → wall-time seconds) at
//! the repo root so future PRs can track scaling regressions.
//!
//! Knobs: `PALLAS_BENCH_N` overrides the sweep's sequence length.

use prescored::attention::{
    flash_attention, prescored_hyper_attention, rel_error, AttentionInputs, HyperConfig,
    PreScoredConfig,
};
use prescored::linalg::Matrix;
use prescored::parallel;
use prescored::prescore::{prescore, KeyBudget, Method, PreScoreConfig};
use prescored::util::bench::{black_box, f, Bencher, Table};
use prescored::util::rng::Rng;

fn overhead_scaling() {
    let d = 64;
    let sizes = [512usize, 1024, 2048, 4096, 8192];
    let b = Bencher { min_samples: 3, max_samples: 6, target_time: 1.0, warmup: 1 };
    let methods: Vec<(&str, Method)> = vec![
        ("kmeans", Method::KMeans),
        ("kmedian", Method::KMedian),
        ("leverage", Method::Leverage { exact: false }),
        ("minibatch", Method::MiniBatch { batch: 256 }),
    ];

    let mut t = Table::new(
        "Pre-scoring overhead vs n (ms) — paper: ≈O(n·d)",
        &["n", "kmeans", "kmedian", "leverage", "minibatch"],
    );
    let mut times: Vec<Vec<f64>> = vec![Vec::new(); methods.len()];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let mut row = vec![n.to_string()];
        for (mi, (_, m)) in methods.iter().enumerate() {
            let cfg = PreScoreConfig {
                method: *m,
                budget: KeyBudget::Fixed(n / 4),
                max_iters: 5,
                ..Default::default()
            };
            let tm = b.time("ps", || black_box(prescore(&k, &cfg))).median();
            times[mi].push(tm);
            row.push(f(tm * 1e3, 2));
        }
        t.row(row);
    }
    t.print();

    println!("\nempirical scaling exponent (log-slope of time vs n; 1.0 = linear):");
    for (mi, (name, _)) in methods.iter().enumerate() {
        let first = times[mi][0];
        let last = *times[mi].last().unwrap();
        let slope = (last / first).log2() / ((sizes[sizes.len() - 1] as f64 / sizes[0] as f64).log2());
        println!("  {name:<10} {:.2}", slope);
    }
}

/// JSON helper: `{"1": 1.23, "2": 0.64}` from (threads, value) pairs.
fn json_map(pairs: &[(usize, f64)]) -> String {
    let body: Vec<String> =
        pairs.iter().map(|(t, v)| format!("\"{t}\": {v:.6}")).collect();
    format!("{{{}}}", body.join(", "))
}

fn parallel_scaling() {
    let n: usize = std::env::var("PALLAS_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8192);
    let d = 64usize;
    println!("\n== parallel engine scaling: n={n} d={d} ==");

    let mut rng = Rng::new(0xbe7c);
    let q = Matrix::randn(n, d, 1.0, &mut rng);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let inp = AttentionInputs::new(&q, &k, &v);
    let ps_cfg = PreScoredConfig {
        prescore: PreScoreConfig {
            budget: KeyBudget::Fixed(n / 4),
            max_iters: 5,
            seed: 3,
            ..Default::default()
        },
        hyper: HyperConfig { block_size: 64, sample_size: 64, seed: 3, ..Default::default() },
        ..Default::default()
    };

    let hw = parallel::num_threads();
    let mut threads: Vec<usize> = vec![1, 2, 4];
    if hw > 4 && !threads.contains(&hw) {
        threads.push(hw);
    }
    threads.retain(|&t| t <= hw.max(4));

    let b = Bencher { min_samples: 2, max_samples: 4, target_time: 2.0, warmup: 1 };
    let flash_base = parallel::with_threads(1, || flash_attention(&inp));
    let ps_base = parallel::with_threads(1, || prescored_hyper_attention(&inp, &ps_cfg).0);

    let mut flash_times: Vec<(usize, f64)> = Vec::new();
    let mut ps_times: Vec<(usize, f64)> = Vec::new();
    let mut flash_errs: Vec<(usize, f64)> = Vec::new();
    let mut ps_errs: Vec<(usize, f64)> = Vec::new();
    let mut table =
        Table::new("Parallel scaling (s)", &["threads", "flash", "prescored+hyper", "err_f", "err_p"]);
    for &t in &threads {
        let tf = parallel::with_threads(t, || b.time("flash", || black_box(flash_attention(&inp))))
            .median();
        let tp = parallel::with_threads(t, || {
            b.time("prescored", || black_box(prescored_hyper_attention(&inp, &ps_cfg)))
        })
        .median();
        let ef = rel_error(&parallel::with_threads(t, || flash_attention(&inp)), &flash_base) as f64;
        let ep = rel_error(
            &parallel::with_threads(t, || prescored_hyper_attention(&inp, &ps_cfg).0),
            &ps_base,
        ) as f64;
        assert!(ef <= 1e-5, "flash threads={t} diverged from serial: {ef}");
        assert!(ep <= 1e-5, "prescored threads={t} diverged from serial: {ep}");
        flash_times.push((t, tf));
        ps_times.push((t, tp));
        flash_errs.push((t, ef));
        ps_errs.push((t, ep));
        table.row(vec![t.to_string(), f(tf, 4), f(tp, 4), format!("{ef:.2e}"), format!("{ep:.2e}")]);
    }
    table.print();
    let speedup = |times: &[(usize, f64)]| -> f64 {
        let t1 = times.iter().find(|(t, _)| *t == 1).map(|(_, v)| *v).unwrap_or(f64::NAN);
        let best = times.iter().map(|(_, v)| *v).fold(f64::INFINITY, f64::min);
        t1 / best
    };
    println!(
        "best speedup vs threads=1: flash {:.2}x, prescored {:.2}x",
        speedup(&flash_times),
        speedup(&ps_times)
    );

    let json = format!(
        "{{\n  \"n\": {n},\n  \"d\": {d},\n  \"threads\": [{}],\n  \
         \"flash_attention_s\": {},\n  \"prescored_hyper_attention_s\": {},\n  \
         \"rel_err_vs_serial\": {{\"flash\": {}, \"prescored\": {}}},\n  \
         \"speedup_best\": {{\"flash\": {:.4}, \"prescored\": {:.4}}}\n}}\n",
        threads.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(", "),
        json_map(&flash_times),
        json_map(&ps_times),
        json_map(&flash_errs),
        json_map(&ps_errs),
        speedup(&flash_times),
        speedup(&ps_times),
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_parallel.json");
    match std::fs::write(out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}

fn main() {
    overhead_scaling();
    parallel_scaling();
}
