//! Key-budget policy comparison — attention-mass (`mass=p`) vs fixed-k at
//! the *same average realized budget*, on the Fig. 2 PPL harness.
//!
//! For each mass target p the bench first runs the mass spec over the eval
//! docs and reads back the per layer·head realized selection sizes (the
//! decode-session states' retained selections), then rounds their mean to
//! pick the matched fixed top-k. Both specs therefore spend the same number
//! of keys on average; the only difference is *where* the mass policy puts
//! them — more keys on heads whose pre-scores are flat, fewer on peaked
//! heads. Dispersion of realized k across heads is reported alongside the
//! two perplexities: zero dispersion means the policies coincide (identical
//! score-order prefixes), and any spread is budget the mass policy moved
//! between heads.
//!
//! Docs are full-length only (the paper's PPL* column) so the comparison is
//! pure cross-head adaptivity, not sequence-length adaptivity.
//!
//! Emits `BENCH_budget.json` at the repo root. Env knobs:
//!
//! * `PALLAS_BUDGET_DOCS`    — number of eval documents (default 3)
//! * `PALLAS_BUDGET_CONTEXT` — document length in tokens (default 256)
//! * `PALLAS_BUDGET_SAMPLE`  — residual sample size (default 16)
//! * `PALLAS_BUDGET_MASS`    — comma list of mass targets (default
//!   `0.5,0.7,0.85,0.95`)
//! * `PALLAS_BUDGET_JSON`    — output path override
//! * `PALLAS_BUDGET_ASSERT`  — when `1`, exit non-zero unless the mass
//!   policy's PPL is ≤ the matched fixed policy's at every target
//! * `PALLAS_BUDGET_TOL`     — relative slack for the assert (default 0)

use prescored::attention::{AttentionSpec, AttnPolicy, Coupling};
use prescored::exp::{eval_docs, ppl_over, prescored_spec};
use prescored::model::{Transformer, TransformerConfig, WeightStore};
use prescored::prescore::{KeyBudget, Method};
use prescored::util::bench::{env_list, env_usize, f, Table};
use std::path::Path;

/// The paper's standard K-means+Hyper spec with the budget swapped for an
/// attention-mass target.
fn mass_spec(p: f32, sample: usize) -> AttentionSpec {
    match prescored_spec(Method::KMeans, 0, sample, Coupling::Glm3Corrected, true) {
        AttentionSpec::PreScored(mut cfg) => {
            cfg.prescore.budget = KeyBudget::Mass(p);
            AttentionSpec::PreScored(cfg)
        }
        _ => unreachable!("prescored_spec builds a PreScored spec"),
    }
}

/// Realized selection size of every layer·head state after prefilling `doc`.
fn realized_lens(model: &Transformer, spec: &AttentionSpec, doc: &[u32]) -> Vec<usize> {
    let policy = AttnPolicy::uniform(spec.clone());
    let (_, sess) = model.begin_decode(doc, &policy).expect("prescored spec supports decode");
    sess.states().iter().filter_map(|s| s.selection().map(|sel| sel.len())).collect()
}

struct TargetResult {
    mass: f32,
    avg_realized: f64,
    fixed_k: usize,
    k_min: usize,
    k_max: usize,
    k_std: f64,
    ppl_mass: f64,
    ppl_fixed: f64,
}

fn main() {
    let n_docs = env_usize("PALLAS_BUDGET_DOCS", 3);
    let context = env_usize("PALLAS_BUDGET_CONTEXT", 256);
    let sample = env_usize("PALLAS_BUDGET_SAMPLE", 16);
    let masses = env_list::<f32>("PALLAS_BUDGET_MASS", &[0.5, 0.7, 0.85, 0.95]);
    let assert_win = std::env::var("PALLAS_BUDGET_ASSERT").map_or(false, |v| v == "1");
    let tol: f64 = std::env::var("PALLAS_BUDGET_TOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let json_path =
        std::env::var("PALLAS_BUDGET_JSON").unwrap_or_else(|_| "BENCH_budget.json".into());

    let dir = Path::new("artifacts");
    let model = if dir.join("weights.bin").exists() {
        let ws = WeightStore::load(&dir.join("weights.bin")).unwrap();
        Transformer::from_weights(&ws, TransformerConfig::default())
    } else {
        eprintln!("artifacts missing — using random weights");
        Transformer::random(TransformerConfig::default(), 1)
    };
    let docs = eval_docs(512, context, n_docs, true, 33_000);

    let mut t = Table::new(
        "Key-budget policy — mass=p vs fixed-k at equal average realized budget (PPL*)",
        &["Mass p", "Avg k", "Fixed k", "k min", "k max", "k std", "PPL mass", "PPL fixed"],
    );
    let mut results: Vec<TargetResult> = Vec::new();
    for &p in &masses {
        let mspec = mass_spec(p, sample);
        // Average realized budget across every doc × layer·head state, and
        // cross-head dispersion at the first (full-length) doc.
        let mut all: Vec<usize> = Vec::new();
        for d in &docs {
            all.extend(realized_lens(&model, &mspec, d));
        }
        assert!(!all.is_empty(), "mass spec retained no selections");
        let avg = all.iter().sum::<usize>() as f64 / all.len() as f64;
        let head_lens = realized_lens(&model, &mspec, &docs[0]);
        let k_min = *head_lens.iter().min().expect("non-empty");
        let k_max = *head_lens.iter().max().expect("non-empty");
        let hmean = head_lens.iter().sum::<usize>() as f64 / head_lens.len() as f64;
        let k_std = (head_lens.iter().map(|&k| (k as f64 - hmean).powi(2)).sum::<f64>()
            / head_lens.len() as f64)
            .sqrt();

        let fixed_k = (avg.round() as usize).max(1);
        let fspec = prescored_spec(Method::KMeans, fixed_k, sample, Coupling::Glm3Corrected, true);
        let ppl_mass = ppl_over(&model, &mspec, &docs);
        let ppl_fixed = ppl_over(&model, &fspec, &docs);

        t.row(vec![
            f(p as f64, 2),
            f(avg, 1),
            fixed_k.to_string(),
            k_min.to_string(),
            k_max.to_string(),
            f(k_std, 2),
            f(ppl_mass, 3),
            f(ppl_fixed, 3),
        ]);
        results.push(TargetResult {
            mass: p,
            avg_realized: avg,
            fixed_k,
            k_min,
            k_max,
            k_std,
            ppl_mass,
            ppl_fixed,
        });
    }
    t.print();

    let entry = |r: &TargetResult| {
        format!(
            "{{\"mass\": {:.4}, \"avg_realized_k\": {:.2}, \"fixed_k\": {}, \"k_min\": {}, \
             \"k_max\": {}, \"k_std\": {:.3}, \"ppl_mass\": {:.4}, \"ppl_fixed\": {:.4}}}",
            r.mass, r.avg_realized, r.fixed_k, r.k_min, r.k_max, r.k_std, r.ppl_mass, r.ppl_fixed,
        )
    };
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"docs\": {n_docs},\n  \"context\": {context},\n  \"sample\": {sample},\n"
    ));
    json.push_str("  \"targets\": [\n");
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {}{}\n",
            entry(r),
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&json_path, json).expect("writing BENCH_budget.json");
    println!("wrote {json_path}");

    if assert_win {
        // CI gate: at the same average spend, adaptive allocation must not
        // lose to uniform allocation. Zero cross-head dispersion makes the
        // two selections identical (both are score-order prefixes), so the
        // comparison can tie but a regression means the mass resolver is
        // placing budget on the wrong heads.
        for r in &results {
            if r.ppl_mass > r.ppl_fixed * (1.0 + tol) {
                eprintln!(
                    "BUDGET ASSERT FAILED: mass={} ppl {} > fixed_k={} ppl {} (tol {})",
                    f(r.mass as f64, 2),
                    f(r.ppl_mass, 4),
                    r.fixed_k,
                    f(r.ppl_fixed, 4),
                    tol,
                );
                std::process::exit(1);
            }
        }
        println!(
            "budget assert passed: mass PPL ≤ fixed PPL at equal average budget on all {} targets",
            results.len()
        );
    }
}
