//! §4 structural guarantees: Theorem 4.4 (leverage separation), Theorem 4.5
//! (k-means recovery), Corollary 4.6 (singleton case), Claim 4.7 (ℓp), the
//! Appendix-B counterexample, and the LevAttention universal-set property
//! under polynomial attention.

use prescored::attention::polynomial::{key_max_weights, polynomial_attention_matrix};
use prescored::attention::AttentionInputs;
use prescored::clustering::{kmeans_best_of, minkowski_kmeans, partitions_match};
use prescored::data::planted::{appendix_b_counterexample, generate, PlantedConfig};
use prescored::prescore::leverage::{leverage_scores_exact, universal_set};
use prescored::util::bench::{f, Table};
use prescored::util::rng::Rng;

fn main() {
    let trials = 10;

    // Thm 4.4 + Thm 4.5 + Claim 4.7 across trials.
    let mut t = Table::new(
        "Theorems 4.4/4.5, Claim 4.7 — recovery rates over trials (planted model)",
        &["d", "eps", "lev-gap (min)", "kmeans rec.", "l1 rec.", "l3 rec."],
    );
    for &(d, eps) in &[(4usize, 0.25f64), (6, 0.25), (8, 0.5)] {
        let mut gap_min = f64::INFINITY;
        let (mut km, mut l1, mut l3) = (0, 0, 0);
        for trial in 0..trials {
            let cfg = PlantedConfig { n: 400, d, epsilon: eps, seed: trial as u64, ..Default::default() };
            let inst = generate(&cfg);
            let h = leverage_scores_exact(&inst.matrix);
            let min_sig =
                inst.signal_rows.iter().map(|&i| h[i]).fold(f32::INFINITY, f32::min) as f64;
            let max_noise = (0..cfg.n)
                .filter(|&i| inst.labels[i] == 0)
                .map(|i| h[i] as f64)
                .fold(0.0, f64::max);
            gap_min = gap_min.min(min_sig / max_noise.max(1e-12));
            let mut rng = Rng::new(trial as u64 + 100);
            if partitions_match(
                &kmeans_best_of(&inst.matrix, d + 1, 20, 5, &mut rng).assignment,
                &inst.labels,
            ) {
                km += 1;
            }
            if partitions_match(
                &minkowski_kmeans(&inst.matrix, d + 1, 1.0, 20, &mut rng).assignment,
                &inst.labels,
            ) {
                l1 += 1;
            }
            if partitions_match(
                &minkowski_kmeans(&inst.matrix, d + 1, 3.0, 20, &mut rng).assignment,
                &inst.labels,
            ) {
                l3 += 1;
            }
        }
        t.row(vec![
            d.to_string(),
            eps.to_string(),
            f(gap_min, 1),
            format!("{km}/{trials}"),
            format!("{l1}/{trials}"),
            format!("{l3}/{trials}"),
        ]);
    }
    t.print();

    // Corollary 4.6: singleton case m = 1.
    let mut singles_total = 0;
    let mut sig_total = 0;
    for trial in 0..trials {
        let cfg = PlantedConfig {
            n: 300,
            d: 5,
            epsilon: 1.0,
            c_s: 0.002,
            seed: 50 + trial as u64,
            ..Default::default()
        };
        let inst = generate(&cfg);
        let mut rng = Rng::new(trial as u64);
        let c = kmeans_best_of(&inst.matrix, cfg.d + 1, 20, 5, &mut rng);
        let sizes = c.sizes();
        singles_total +=
            inst.signal_rows.iter().filter(|&&i| sizes[c.assignment[i]] == 1).count();
        sig_total += inst.signal_rows.len();
    }
    println!("\nCorollary 4.6 — singleton signal clusters: {singles_total}/{sig_total}");

    // LevAttention universal set under polynomial attention: U = {h >= eps}
    // must contain every key receiving a heavy polynomial-attention weight.
    let cfg = PlantedConfig { n: 400, d: 6, epsilon: 0.25, ..Default::default() };
    let inst = generate(&cfg);
    let h = leverage_scores_exact(&inst.matrix);
    let u = universal_set(&h, 0.1);
    let attn = polynomial_attention_matrix(
        &AttentionInputs::new(&inst.matrix, &inst.matrix, &inst.matrix),
        4,
    );
    let heavy = key_max_weights(&attn);
    let missed = (0..cfg.n)
        .filter(|&j| heavy[j] >= 0.25 && !u.contains(&j))
        .count();
    println!(
        "Universal set: |U| = {} of {}; ε-heavy keys missed by U: {missed} (must be 0)",
        u.len(),
        cfg.n
    );

    // Appendix B.
    let mut raw_iso = 0;
    let mut norm_iso = 0;
    for trial in 0..trials {
        let (a, sig) = appendix_b_counterexample(64, 8, 50.0, trial as u64);
        let mut rng = Rng::new(trial as u64 + 7);
        let raw = kmeans_best_of(&a, sig + 1, 20, 10, &mut rng);
        raw_iso += (0..sig).map(|i| raw.assignment[i]).collect::<std::collections::HashSet<_>>().len();
        let mut an = a.clone();
        an.l2_normalize_rows(1e-12);
        let nm = kmeans_best_of(&an, sig + 1, 20, 10, &mut rng);
        norm_iso += (0..sig).map(|i| nm.assignment[i]).collect::<std::collections::HashSet<_>>().len();
    }
    println!(
        "Appendix B — distinct signal clusters (of {} possible): unnormalized {raw_iso}, ℓ2-normalized {norm_iso}",
        4 * trials
    );
}
