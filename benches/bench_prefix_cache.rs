//! Shared-prefix cache: cold vs warm prefill latency × shared-prefix
//! fraction × threads.
//!
//! The serving claim under test (the prefix-cache PR's tentpole): a warm
//! hit on an L-token prefix reconstructs the session from cached KV +
//! pre-score artifacts and runs the forward only over the n−L suffix —
//! O(suffix) work — while a cold prefill pays the full O(n²) causal
//! attention. The warm/cold ratio should therefore fall roughly like
//! 1 − f² for shared fraction f.
//!
//! Emits `BENCH_prefix.json` at the repo root:
//! `ms[threads][frac] = {cold_ms, warm_ms, speedup}`.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_PREFIX_CONTEXT` — context length, default 1024
//! * `PALLAS_PREFIX_FRACS`   — comma list of shared fractions, default
//!   `0.25,0.5,0.75,0.9`
//! * `PALLAS_PREFIX_D`       — d_model, default 64
//! * `PALLAS_PREFIX_REPS`    — timing repetitions, default 3
//! * `PALLAS_PREFIX_JSON`    — output path override (CI smoke points it at
//!   a scratch file so real baselines aren't clobbered)
//! * `PALLAS_PREFIX_ASSERT`  — when `1`, exit non-zero unless the warm hit
//!   beats cold at the largest shared fraction (the CI gate)

use prescored::attention::AttnPolicy;
use prescored::model::{DecodeSession, Transformer, TransformerConfig};
use prescored::parallel;
use prescored::util::bench::{env_list, env_usize, f, median_ms};
use prescored::util::rng::Rng;

fn main() {
    let context = env_usize("PALLAS_PREFIX_CONTEXT", 1024);
    let d_model = env_usize("PALLAS_PREFIX_D", 64);
    let reps = env_usize("PALLAS_PREFIX_REPS", 3);
    let fracs = env_list("PALLAS_PREFIX_FRACS", &[0.25, 0.5, 0.75, 0.9]);
    let assert_win = std::env::var("PALLAS_PREFIX_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_PREFIX_JSON").unwrap_or_else(|_| "BENCH_prefix.json".into());

    let pool_width = parallel::num_threads().max(2);
    parallel::set_threads(pool_width);
    let thread_counts = [1usize, pool_width];

    let tcfg = TransformerConfig {
        vocab: 256,
        d_model,
        n_layers: 2,
        n_heads: 2,
        max_seq: context,
    };
    let model = Transformer::random(tcfg, 0xbe9c);
    // Flash: the suffix-stable spec the serving engine serves partial warm
    // hits for (rank/selection kernels dedup at full length instead).
    let policy = AttnPolicy::parse("flash").unwrap();
    let mut rng = Rng::new(0x9efc);
    let tokens: Vec<u32> = (0..context).map(|_| rng.usize(256) as u32).collect();

    println!(
        "== prefix cache: cold vs warm prefill @ context {context}, d_model {d_model}, \
         threads {{1, {pool_width}}} =="
    );

    // results[thread_idx][frac_idx] = (cold_ms, warm_ms)
    let mut results = vec![vec![(0.0f64, 0.0f64); fracs.len()]; thread_counts.len()];
    for (ti, &threads) in thread_counts.iter().enumerate() {
        parallel::with_threads(threads, || {
            let cold_ms = median_ms(reps, || {
                model.begin_decode(&tokens, &policy).expect("cold prefill")
            });
            for (fi, &frac) in fracs.iter().enumerate() {
                let prefix_len = ((context as f64 * frac) as usize).clamp(1, context - 1);
                // The donor prefill is what a previous request already paid;
                // the warm path clones the snapshot (the cache's
                // copy-on-write branch) and resumes over the suffix — both
                // sides of that are timed.
                let (_, donor) =
                    model.begin_decode(&tokens[..prefix_len], &policy).expect("donor");
                let kv = donor.export_kv();
                let states = donor.clone_states();
                let warm_ms = median_ms(reps, || {
                    let mut sess =
                        DecodeSession::from_cache(kv.clone(), states.clone(), prefix_len);
                    model.resume_decode(&mut sess, &tokens[prefix_len..], &policy)
                });
                results[ti][fi] = (cold_ms, warm_ms);
                println!(
                    "threads {threads:>2} | shared {:>5}% | cold {:>9} ms | warm {:>9} ms | \
                     speedup {:>6}x",
                    f(frac * 100.0, 0),
                    f(cold_ms, 2),
                    f(warm_ms, 2),
                    f(cold_ms / warm_ms.max(1e-9), 2),
                );
            }
        });
    }

    // JSON emission.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"context\": {context},\n  \"d_model\": {d_model},\n"));
    json.push_str("  \"spec\": \"flash\",\n  \"ms\": {\n");
    for (ti, &threads) in thread_counts.iter().enumerate() {
        json.push_str(&format!("    \"{threads}\": {{\n"));
        for (fi, &frac) in fracs.iter().enumerate() {
            let (cold, warm) = results[ti][fi];
            json.push_str(&format!(
                "      \"{frac}\": {{\"cold_ms\": {cold:.4}, \"warm_ms\": {warm:.4}, \
                 \"speedup\": {:.4}}}{}\n",
                cold / warm.max(1e-9),
                if fi + 1 < fracs.len() { "," } else { "" }
            ));
        }
        json.push_str(&format!(
            "    }}{}\n",
            if ti + 1 < thread_counts.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    std::fs::write(&json_path, json).expect("writing BENCH_prefix.json");
    println!("wrote {json_path}");

    if assert_win {
        // CI gate: at the largest shared fraction, the warm hit must beat
        // the cold prefill at every thread count.
        let last = fracs.len() - 1;
        for (ti, &threads) in thread_counts.iter().enumerate() {
            let (cold, warm) = results[ti][last];
            if warm >= cold {
                eprintln!(
                    "PREFIX CACHE REGRESSION: warm {warm:.3} ms >= cold {cold:.3} ms at \
                     shared fraction {} (threads {threads})",
                    fracs[last]
                );
                std::process::exit(1);
            }
        }
        println!("warm-beats-cold assertion passed");
    }
}
