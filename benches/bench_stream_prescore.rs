//! Streaming pre-scoring: decode-refresh cost (full re-cluster vs stream
//! fold+merge) × context × threads, plus warm-hit prefill latency for the
//! `prescored:...,mode=stream` spec.
//!
//! The tentpole claims under test:
//!
//! 1. A stream-mode selection refresh folds only the keys seen since the
//!    last refresh — O(|new|·k·d) — while a full-mode refresh re-runs
//!    Algorithm 1 over all n keys — O(n·d·k·I). The per-refresh cost must
//!    therefore be (a) much cheaper and (b) flat in the context length,
//!    which the emitted table makes visible per context.
//! 2. Because stream mode is suffix-stable, the prefix cache serves it
//!    O(suffix) partial warm hits: warm resume beats the cold prefill.
//!
//! Emits `BENCH_stream.json` at the repo root.
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_STREAM_CONTEXTS`     — comma list, default `1024,4096,16384`
//! * `PALLAS_STREAM_D`            — key dim / d_model, default 64
//! * `PALLAS_STREAM_TOPK`         — selection budget, default 64
//! * `PALLAS_STREAM_REFRESH`      — keys folded per refresh, default 16
//! * `PALLAS_STREAM_REPS`         — timing repetitions, default 5
//! * `PALLAS_STREAM_WARM_CONTEXT` — transformer warm-hit context, default
//!   512 (0 skips the warm section)
//! * `PALLAS_STREAM_FRACS`        — shared-prefix fractions, default `0.5,0.9`
//! * `PALLAS_STREAM_JSON`         — output path override
//! * `PALLAS_STREAM_ASSERT`       — when `1`, exit non-zero unless the
//!   stream refresh beats the full re-cluster at every context and thread
//!   count (the CI gate)

use prescored::attention::AttnPolicy;
use prescored::linalg::Matrix;
use prescored::model::{DecodeSession, Transformer, TransformerConfig};
use prescored::parallel;
use prescored::prescore::{prescore, PreScoreConfig, StreamPrescorer};
use prescored::util::bench::{black_box, env_list, env_usize, f, median_ms};
use prescored::util::rng::Rng;
use std::time::Instant;

fn main() {
    let contexts: Vec<usize> =
        env_list("PALLAS_STREAM_CONTEXTS", &[1024usize, 4096, 16384]);
    let d = env_usize("PALLAS_STREAM_D", 64);
    let top_k = env_usize("PALLAS_STREAM_TOPK", 64);
    let refresh = env_usize("PALLAS_STREAM_REFRESH", 16);
    let reps = env_usize("PALLAS_STREAM_REPS", 5);
    let warm_context = env_usize("PALLAS_STREAM_WARM_CONTEXT", 512);
    let fracs = env_list("PALLAS_STREAM_FRACS", &[0.5, 0.9]);
    let assert_win = std::env::var("PALLAS_STREAM_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_STREAM_JSON").unwrap_or_else(|_| "BENCH_stream.json".into());

    let pool_width = parallel::num_threads().max(2);
    parallel::set_threads(pool_width);
    let thread_counts = [1usize, pool_width];
    let cfg = PreScoreConfig { top_k, seed: 7, ..Default::default() };

    println!(
        "== stream pre-scoring: refresh cost (full re-cluster vs stream fold) @ d {d}, \
         top_k {top_k}, {refresh} new keys/refresh, threads {{1, {pool_width}}} =="
    );

    // refresh_ms[thread_idx][ctx_idx] = (full_ms, stream_ms)
    let mut refresh_ms = vec![vec![(0.0f64, 0.0f64); contexts.len()]; thread_counts.len()];
    let mut regression = false;
    let bursts = (reps * 4).max(8);
    for (ti, &threads) in thread_counts.iter().enumerate() {
        parallel::with_threads(threads, || {
            for (ci, &n) in contexts.iter().enumerate() {
                let mut rng = Rng::new(0x57e0 + n as u64);
                let keys = Matrix::randn(n + refresh * bursts, d, 1.0, &mut rng);
                // Full-mode refresh: Algorithm 1 over all n+R keys.
                let full_ms = median_ms(reps, || {
                    prescore(&keys.slice_rows(0, n + refresh), &cfg).selected.len()
                });
                // Stream refresh: the state already covers the first n keys;
                // a refresh folds the R new ones and merges the selection.
                // Timed as `bursts` consecutive refreshes over one state
                // (clone outside the timer), so per-refresh cost carries no
                // state-copy overhead and amortizes timer noise.
                let mut seeded = StreamPrescorer::new(cfg.clone(), d);
                seeded.fold_to(&keys.slice_rows(0, n));
                let stream_ms = {
                    let mut p = seeded.clone();
                    let t0 = Instant::now();
                    p.fold_to(&keys);
                    let total = t0.elapsed().as_secs_f64() * 1e3;
                    black_box(p.selection().len());
                    total / bursts as f64
                };
                refresh_ms[ti][ci] = (full_ms, stream_ms);
                if stream_ms >= full_ms {
                    regression = true;
                }
                println!(
                    "threads {threads:>2} | context {n:>6} | full {:>10} ms | stream {:>8} ms \
                     | speedup {:>8}x",
                    f(full_ms, 3),
                    f(stream_ms, 3),
                    f(full_ms / stream_ms.max(1e-9), 1),
                );
            }
        });
    }

    // Warm-hit prefill: stream spec through the transformer + prefix-cache
    // resume path (cold full prefill vs snapshot-clone + suffix replay).
    let spec = format!("prescored:kmeans,top_k={top_k},block=32,sample=8,mode=stream");
    let mut warm_results = vec![vec![(0.0f64, 0.0f64); fracs.len()]; thread_counts.len()];
    if warm_context > 0 {
        println!("\n== warm-hit prefill for '{spec}' @ context {warm_context} ==");
        let tcfg = TransformerConfig {
            vocab: 256,
            d_model: d,
            n_layers: 2,
            n_heads: 2,
            max_seq: warm_context,
        };
        let model = Transformer::random(tcfg, 0xbe9d);
        let policy = AttnPolicy::parse(&spec).expect("stream spec parses");
        let mut rng = Rng::new(0x9efd);
        let tokens: Vec<u32> = (0..warm_context).map(|_| rng.usize(256) as u32).collect();
        for (ti, &threads) in thread_counts.iter().enumerate() {
            parallel::with_threads(threads, || {
                let cold_ms = median_ms(reps, || {
                    model.begin_decode(&tokens, &policy).expect("cold prefill")
                });
                for (fi, &frac) in fracs.iter().enumerate() {
                    let prefix_len =
                        ((warm_context as f64 * frac) as usize).clamp(1, warm_context - 1);
                    let (_, donor) =
                        model.begin_decode(&tokens[..prefix_len], &policy).expect("donor");
                    let kv = donor.export_kv();
                    let states = donor.clone_states();
                    let warm_ms = median_ms(reps, || {
                        let mut sess =
                            DecodeSession::from_cache(kv.clone(), states.clone(), prefix_len);
                        model.resume_decode(&mut sess, &tokens[prefix_len..], &policy)
                    });
                    warm_results[ti][fi] = (cold_ms, warm_ms);
                    println!(
                        "threads {threads:>2} | shared {:>5}% | cold {:>9} ms | warm {:>9} ms \
                         | speedup {:>6}x",
                        f(frac * 100.0, 0),
                        f(cold_ms, 2),
                        f(warm_ms, 2),
                        f(cold_ms / warm_ms.max(1e-9), 2),
                    );
                }
            });
        }
    }

    // JSON emission.
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"d\": {d},\n  \"top_k\": {top_k},\n  \"refresh\": {refresh},\n"
    ));
    json.push_str(&format!("  \"spec\": \"{spec}\",\n  \"refresh_ms\": {{\n"));
    for (ti, &threads) in thread_counts.iter().enumerate() {
        json.push_str(&format!("    \"{threads}\": {{\n"));
        for (ci, &n) in contexts.iter().enumerate() {
            let (full, stream) = refresh_ms[ti][ci];
            json.push_str(&format!(
                "      \"{n}\": {{\"full_ms\": {full:.5}, \"stream_ms\": {stream:.5}, \
                 \"speedup\": {:.3}}}{}\n",
                full / stream.max(1e-9),
                if ci + 1 < contexts.len() { "," } else { "" }
            ));
        }
        json.push_str(&format!(
            "    }}{}\n",
            if ti + 1 < thread_counts.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"warm\": {\n");
    // Skipped warm section (warm_context = 0) emits an empty object, not
    // zero-filled rows a consumer would read as a measured regression.
    if warm_context > 0 {
        for (ti, &threads) in thread_counts.iter().enumerate() {
            json.push_str(&format!("    \"{threads}\": {{\n"));
            for (fi, &frac) in fracs.iter().enumerate() {
                let (cold, warm) = warm_results[ti][fi];
                json.push_str(&format!(
                    "      \"{frac}\": {{\"cold_ms\": {cold:.4}, \"warm_ms\": {warm:.4}, \
                     \"speedup\": {:.4}}}{}\n",
                    cold / warm.max(1e-9),
                    if fi + 1 < fracs.len() { "," } else { "" }
                ));
            }
            json.push_str(&format!(
                "    }}{}\n",
                if ti + 1 < thread_counts.len() { "," } else { "" }
            ));
        }
    }
    json.push_str("  }\n}\n");
    std::fs::write(&json_path, json).expect("writing BENCH_stream.json");
    println!("wrote {json_path}");

    if assert_win {
        if regression {
            eprintln!(
                "STREAM REFRESH REGRESSION: stream fold+merge did not beat the full \
                 re-cluster at some context/thread count (see table above)"
            );
            std::process::exit(1);
        }
        println!("stream-beats-full-recluster assertion passed");
    }
}
