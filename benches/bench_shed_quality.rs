//! Degradation-ladder quality/throughput tradeoff vs outright rejection.
//!
//! The load-shedding claim under test (the fault-tolerance PR's tentpole):
//! under a page pool too small for the offered load, *degrading* admissions
//! down the ladder (smaller top_k → staler refresh → l2norm → shorter
//! answers) completes strictly more tokens than *rejecting* the overflow —
//! while reporting the served spec truthfully. Each run pins the shedder to
//! one rung (`shed_pin_rung`) and offers the identical request burst; the
//! reject baseline runs the same burst with `shed_mode = "reject"`.
//!
//! Emits `BENCH_shed.json` at the repo root: per rung {spec, completed,
//! completed_tokens, tokens_per_s, ppl, p50_ms, p99_ms, degraded} plus the
//! reject baseline (with its refusal count).
//!
//! Knobs (the CI smoke run shrinks them):
//! * `PALLAS_SHED_REQUESTS` — offered burst size, default 12
//! * `PALLAS_SHED_CONTEXT`  — prompt length, default 48
//! * `PALLAS_SHED_NEW`      — decode budget per request, default 16
//! * `PALLAS_SHED_JSON`     — output path override
//! * `PALLAS_SHED_ASSERT`   — when `1`, exit non-zero unless every rung
//!   completes at least as many tokens as the reject baseline (the CI gate)

use prescored::attention::AttentionSpec;
use prescored::config::ServingConfig;
use prescored::coordinator::{Request, ServerError};
use prescored::data::corpus;
use prescored::model::{Transformer, TransformerConfig};
use prescored::server::shed::build_ladder;
use prescored::server::ScoringServer;
use prescored::util::bench::{env_usize, f};
use std::time::Instant;

const SPEC: &str = "prescored:kmeans,top_k=32,block=16,sample=4";

struct RunResult {
    label: String,
    spec: String,
    completed: usize,
    completed_tokens: usize,
    tokens_per_s: f64,
    ppl: f64,
    p50_ms: f64,
    p99_ms: f64,
    degraded: usize,
    rejected: usize,
}

fn run_once(
    label: &str,
    cfg: ServingConfig,
    n_req: u64,
    context: usize,
    n_new: usize,
) -> RunResult {
    let tcfg =
        TransformerConfig { vocab: 256, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 128 };
    let model = Transformer::random(tcfg, 0x5ed);
    let server = ScoringServer::start_with_model(cfg, model).expect("server start");
    let started = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let mut req = Request::scoring(i, corpus::generate(256, context, 7000 + i));
        req.generate = n_new;
        rxs.push(server.submit(req));
    }
    let mut completed = 0usize;
    let mut completed_tokens = 0usize;
    let mut served_spec = String::new();
    let mut ppl_sum = 0.0f64;
    let mut rejected = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        match &resp.error {
            None => {
                completed += 1;
                completed_tokens += resp.generated.len();
                ppl_sum += resp.perplexity();
                served_spec = resp.spec.clone();
            }
            Some(ServerError::Capacity(_)) => rejected += 1,
            Some(other) => panic!("unexpected failure under load: {other:?}"),
        }
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    let stats = server.shutdown();
    assert_eq!(
        stats.kv_pages_acquired, stats.kv_pages_released,
        "{label}: page accounting must balance under pressure"
    );
    RunResult {
        label: label.to_string(),
        spec: served_spec,
        completed,
        completed_tokens,
        tokens_per_s: completed_tokens as f64 / elapsed,
        ppl: if completed > 0 { ppl_sum / completed as f64 } else { 0.0 },
        p50_ms: stats.latency_p50_ms,
        p99_ms: stats.latency_p99_ms,
        degraded: stats.degraded,
        rejected,
    }
}

fn main() {
    let n_req = env_usize("PALLAS_SHED_REQUESTS", 12) as u64;
    let context = env_usize("PALLAS_SHED_CONTEXT", 48);
    let n_new = env_usize("PALLAS_SHED_NEW", 16);
    let assert_win = std::env::var("PALLAS_SHED_ASSERT").map_or(false, |v| v == "1");
    let json_path =
        std::env::var("PALLAS_SHED_JSON").unwrap_or_else(|_| "BENCH_shed.json".into());

    // A pool sized for ~one session at a time: pages_for(context + n_new)
    // with 16-token pages. The burst therefore *must* shed.
    let kv_blocks = (context + n_new).div_ceil(16);
    let base_cfg = || ServingConfig {
        artifacts_dir: "/nonexistent-artifacts".into(),
        variant: "exact".into(),
        max_seq: 128,
        attention_spec: SPEC.into(),
        kv_blocks,
        decode_max_new: n_new,
        prefix_cache_blocks: 0,
        ..Default::default()
    };
    let spec = AttentionSpec::parse(SPEC).expect("spec");
    let ladder = build_ladder(&spec, n_new, 16, ServingConfig::default().shed_min_top_k);

    println!(
        "== degrade-vs-reject under pressure: {n_req} requests × ({context} ctx + {n_new} \
         new), kv pool {kv_blocks} pages, {} rungs ==",
        ladder.len()
    );

    let mut runs: Vec<RunResult> = Vec::new();
    for (r, rung) in ladder.iter().enumerate() {
        let mut cfg = base_cfg();
        cfg.shed_pin_rung = Some(r);
        let res = run_once(&format!("rung {r}"), cfg, n_req, context, n_new);
        println!(
            "rung {r} [{}] | completed {:>3}/{n_req} | tokens {:>4} | ppl {:>8} | p50 {:>8} \
             ms | p99 {:>8} ms",
            rung.spec_str,
            res.completed,
            res.completed_tokens,
            f(res.ppl, 3),
            f(res.p50_ms, 2),
            f(res.p99_ms, 2),
        );
        runs.push(res);
    }
    let mut cfg = base_cfg();
    cfg.shed_mode = "reject".into();
    cfg.shed_pin_rung = Some(0);
    let reject = run_once("reject", cfg, n_req, context, n_new);
    println!(
        "reject [{}] | completed {:>3}/{n_req} | tokens {:>4} | refused {:>3} | ppl {:>8} | \
         p50 {:>8} ms | p99 {:>8} ms",
        SPEC,
        reject.completed,
        reject.completed_tokens,
        reject.rejected,
        f(reject.ppl, 3),
        f(reject.p50_ms, 2),
        f(reject.p99_ms, 2),
    );

    let entry = |r: &RunResult| {
        format!(
            "{{\"label\": \"{}\", \"spec\": \"{}\", \"completed\": {}, \
             \"completed_tokens\": {}, \"tokens_per_s\": {:.4}, \"ppl\": {:.4}, \
             \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \"degraded\": {}, \"rejected\": {}}}",
            r.label,
            r.spec,
            r.completed,
            r.completed_tokens,
            r.tokens_per_s,
            r.ppl,
            r.p50_ms,
            r.p99_ms,
            r.degraded,
            r.rejected,
        )
    };
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"offered_requests\": {n_req},\n  \"context\": {context},\n  \"n_new\": \
         {n_new},\n  \"kv_blocks\": {kv_blocks},\n"
    ));
    json.push_str("  \"rungs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        json.push_str(&format!(
            "    {}{}\n",
            entry(r),
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"reject\": {}\n}}\n", entry(&reject)));
    std::fs::write(&json_path, json).expect("writing BENCH_shed.json");
    println!("wrote {json_path}");

    if assert_win {
        // CI gate: degrade-don't-reject must never complete fewer tokens
        // than refusing the overflow outright, at any rung.
        for r in &runs {
            if r.completed_tokens < reject.completed_tokens {
                eprintln!(
                    "SHED REGRESSION: {} completed {} tokens < reject baseline {}",
                    r.label, r.completed_tokens, reject.completed_tokens
                );
                std::process::exit(1);
            }
        }
        println!("degrade-beats-reject assertion passed");
    }
}
